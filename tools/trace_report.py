"""Trace analyzer: turn a flight-recorder trace into verified numbers.

Consumes the Chrome ``trace_event`` JSON that ``serve.py --trace-out``
writes (see docs/OBSERVABILITY.md) and computes, from the trace alone:

* **overlap** — per engine lane, the fraction of migrated-prefill busy
  time (iterations executing prefill chunks whose head ran on another
  device and crossed the wire — the Cronus remainder) that also decoded
  earlier requests in the same iteration. This is the paper's Figure-1
  mechanism stated mechanically: Cronus overlaps the high-end GPU's
  remaining prefill with decode (overlap fraction > 0), while pure
  disaggregation serializes them (a decode-only instance runs no
  migrated prefill chunks at all, so its fraction is 0);
* **bubbles** — per lane, the fraction of its active span spent idle
  between iterations (prefill bubbles on prefill-capable lanes);
* **TTFT decomposition** — per finished request, queueing (submit →
  first slot admission) and service (admission → last first-token
  timestamp) from the instants alone, aggregated with the same
  percentiles as ``aggregate(queueing=True)`` — the cross-check that
  the trace tells the same story as the metrics (tolerance 1e-6).

``--check`` validates the trace's structure (JSON shape, per-track
monotonic timestamps, properly nested spans, every flow id pairing one
send with one receive, async lifelines balanced); ``--min-overlap`` /
``--max-overlap`` turn the overlap fraction into a CI assertion.

Usage:
  python tools/trace_report.py run.json [--check]
      [--min-overlap X] [--max-overlap X] [--out report.json]
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from typing import Dict, List, Optional, Tuple

EPS = 1e-6     # µs-scale slack for span-nesting comparisons


def load_events(path: str) -> List[dict]:
    """Events from an exported trace file ({"traceEvents": [...]} or a
    bare event list)."""
    with open(path) as f:
        data = json.load(f)
    return data["traceEvents"] if isinstance(data, dict) else data


def track_names(events: List[dict]) -> Dict[Tuple[int, int], str]:
    """(pid, tid) -> human lane label, from the naming metadata."""
    procs: Dict[int, str] = {}
    threads: Dict[Tuple[int, int], str] = {}
    for e in events:
        if e.get("ph") != "M":
            continue
        if e["name"] == "process_name":
            procs[e["pid"]] = e["args"]["name"]
        elif e["name"] == "thread_name":
            threads[(e["pid"], e["tid"])] = e["args"]["name"]
    out = {}
    for (pid, tid), thread in threads.items():
        proc = procs.get(pid, str(pid))
        out[(pid, tid)] = proc if thread == "main" else f"{proc}/{thread}"
    return out


# ---------------------------------------------------------------------------
# structural validation (--check)
# ---------------------------------------------------------------------------

def validate(events: List[dict]) -> List[str]:
    """Structural problems in an exported trace (empty list = clean)."""
    problems: List[str] = []
    last_ts: Dict[Tuple[int, int], float] = {}
    spans: Dict[Tuple[int, int], List[Tuple[float, float]]] = {}
    flows: Dict[object, Dict[str, float]] = {}
    asyncs: Dict[object, Dict[str, float]] = {}
    for i, e in enumerate(events):
        ph = e.get("ph")
        if ph is None:
            problems.append(f"event {i}: missing 'ph'")
            continue
        if ph == "M":
            continue
        if "pid" not in e or "tid" not in e or "ts" not in e:
            problems.append(f"event {i} ({ph}): missing pid/tid/ts")
            continue
        key = (e["pid"], e["tid"])
        ts = e["ts"]
        if ts < last_ts.get(key, float("-inf")) - EPS:
            problems.append(
                f"event {i} ({ph} {e.get('name')}): track {key} timestamp "
                f"regressed {last_ts[key]:.3f} -> {ts:.3f}")
        last_ts[key] = max(last_ts.get(key, float("-inf")), ts)
        if ph == "X":
            spans.setdefault(key, []).append((ts, ts + e.get("dur", 0.0)))
        elif ph in ("s", "f"):
            d = flows.setdefault(("flow", e.get("id")), {"s": 0, "f": 0})
            d[ph] += 1
            d.setdefault(f"{ph}_ts", ts)
        elif ph in ("b", "e"):
            d = asyncs.setdefault((e.get("cat"), e.get("id")),
                                  {"b": 0, "e": 0})
            d[ph] += 1
    for key, sp in spans.items():
        open_ends: List[float] = []     # stack of enclosing span ends
        prev_end = float("-inf")
        for t0, t1 in sp:               # file order = sorted by ts
            while open_ends and t0 >= open_ends[-1] - EPS:
                open_ends.pop()
            if open_ends and t1 > open_ends[-1] + EPS:
                problems.append(
                    f"track {key}: span [{t0:.3f}, {t1:.3f}] straddles "
                    f"its enclosing span ending {open_ends[-1]:.3f}")
            elif not open_ends and t0 < prev_end - EPS:
                problems.append(
                    f"track {key}: span [{t0:.3f}, {t1:.3f}] overlaps the "
                    f"previous top-level span ending {prev_end:.3f}")
            open_ends.append(t1)
            prev_end = max(prev_end, t1)
    for (_, fid), d in flows.items():
        if d["s"] != 1 or d["f"] != 1:
            problems.append(f"flow id {fid}: {d['s']} start(s) / "
                            f"{d['f']} end(s), expected exactly 1 + 1")
        elif d["f_ts"] < d["s_ts"] - EPS:
            problems.append(f"flow id {fid}: receive at {d['f_ts']:.3f} "
                            f"precedes send at {d['s_ts']:.3f}")
    for (cat, ident), d in asyncs.items():
        if d["b"] != 1 or d["e"] != 1:
            problems.append(f"async {cat}:{ident}: {d['b']} begin(s) / "
                            f"{d['e']} end(s), expected exactly 1 + 1")
    return problems


# ---------------------------------------------------------------------------
# overlap + bubbles (the paper's Figure-1 mechanism)
# ---------------------------------------------------------------------------

def overlap_report(events: List[dict]) -> Dict:
    """Per-lane and total migrated-prefill/decode overlap fractions."""
    names = track_names(events)
    per: Dict[str, Dict[str, float]] = {}
    for e in events:
        if e.get("ph") != "X" or e.get("name") != "iter":
            continue
        args = e.get("args", {})
        if args.get("migrated_prefill_tokens", 0) <= 0:
            continue
        label = names.get((e["pid"], e["tid"]), str((e["pid"], e["tid"])))
        d = per.setdefault(label, {"migrated_busy_s": 0.0,
                                   "overlapped_s": 0.0})
        dur = e.get("dur", 0.0) / 1e6
        d["migrated_busy_s"] += dur
        if args.get("n_decode", 0) > 0:
            d["overlapped_s"] += dur
    total_m = sum(d["migrated_busy_s"] for d in per.values())
    total_o = sum(d["overlapped_s"] for d in per.values())
    for d in per.values():
        d["overlap_frac"] = (d["overlapped_s"] / d["migrated_busy_s"]
                             if d["migrated_busy_s"] > 0 else 0.0)
    return {"per_track": per,
            "migrated_busy_s": total_m,
            "overlapped_s": total_o,
            "overlap_frac": total_o / total_m if total_m > 0 else 0.0}


def bubble_report(events: List[dict]) -> Dict[str, Dict[str, float]]:
    """Per-lane idle fraction: 1 - busy/span over its iteration spans."""
    names = track_names(events)
    acc: Dict[str, List[float]] = {}
    for e in events:
        if e.get("ph") != "X" or e.get("name") != "iter":
            continue
        label = names.get((e["pid"], e["tid"]), str((e["pid"], e["tid"])))
        t0, t1 = e["ts"] / 1e6, (e["ts"] + e.get("dur", 0.0)) / 1e6
        cur = acc.setdefault(label, [t0, t1, 0.0, 0])
        cur[0] = min(cur[0], t0)
        cur[1] = max(cur[1], t1)
        cur[2] += t1 - t0
        cur[3] += 1
    out = {}
    for label, (t0, t1, busy, n) in acc.items():
        span = t1 - t0
        out[label] = {
            "span_s": span,
            "busy_s": busy,
            "bubble_frac": max(0.0, 1.0 - busy / span) if span > 0 else 0.0,
            "n_iterations": n,
        }
    return out


# ---------------------------------------------------------------------------
# TTFT decomposition (cross-checked against aggregate(queueing=True))
# ---------------------------------------------------------------------------

def _percentile(values: List[float], p: float) -> float:
    # numpy's linear-interpolation percentile, to match
    # repro.core.metrics.percentile exactly
    import numpy as np
    if not values:
        return float("nan")
    return float(np.percentile(np.asarray(values), p))


def ttft_decomposition(events: List[dict]) -> Dict[str, float]:
    """Queueing/service split of TTFT from the instants alone, with the
    exact keys/percentiles of ``aggregate(queueing=True)`` — plus the
    informational per-request KV-transfer wire time."""
    submit: Dict[str, float] = {}
    service_start: Dict[str, float] = {}
    first_token: Dict[str, float] = {}
    wire: Dict[str, float] = {}
    finished, cancelled = set(), set()
    for e in events:
        if e.get("ph") != "i":
            continue
        name = e.get("name")
        req = e.get("args", {}).get("req")
        if req is None:
            continue
        ts = e["ts"] / 1e6
        if name == "submit":
            submit.setdefault(req, ts)
        elif name == "service_start":
            # the metric records only the FIRST admission anywhere
            service_start.setdefault(req, ts)
        elif name == "first_token":
            # later assignments overwrite (the CPI supersedes the PPI
            # view's timestamp); file order is ts-sorted and stable, so
            # the last occurrence is the final metric
            first_token[req] = ts
        elif name == "kv_ingest":
            wire[req] = wire.get(req, 0.0) + e["args"].get("wire_s", 0.0)
        elif name == "finish":
            finished.add(req)
        elif name == "cancel":
            cancelled.add(req)
    done = sorted(finished - cancelled)
    qs = [service_start[r] - submit[r] for r in done
          if r in service_start and r in submit]
    svc = [first_token[r] - service_start[r] for r in done
           if r in first_token and r in service_start]
    wires = [wire[r] for r in done if r in wire]
    return {
        "n_finished": len(done),
        "queueing_p50": _percentile(qs, 50),
        "queueing_p99": _percentile(qs, 99),
        "ttft_service_p99": _percentile(svc, 99),
        "transfer_wire_p50": _percentile(wires, 50) if wires else 0.0,
        "transfer_wire_p99": _percentile(wires, 99) if wires else 0.0,
        "n_with_transfer": len(wires),
    }


def report(events: List[dict]) -> Dict:
    """The full analysis bundle as one JSON-ready dict."""
    return {
        "n_events": len(events),
        "overlap": overlap_report(events),
        "bubbles": bubble_report(events),
        "ttft": ttft_decomposition(events),
    }


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome JSON from serve.py --trace-out")
    ap.add_argument("--check", action="store_true",
                    help="validate trace structure (spans nested, "
                         "per-track monotonic timestamps, flows paired); "
                         "non-zero exit on problems")
    ap.add_argument("--min-overlap", type=float, default=None, metavar="X",
                    help="fail unless total overlap fraction >= X "
                         "(CI: cronus must overlap)")
    ap.add_argument("--max-overlap", type=float, default=None, metavar="X",
                    help="fail unless total overlap fraction <= X "
                         "(CI: pure disaggregation must not)")
    ap.add_argument("--out", default=None, metavar="FILE",
                    help="write the report JSON here too")
    args = ap.parse_args(argv)

    try:
        events = load_events(args.trace)
    except (OSError, json.JSONDecodeError, KeyError) as e:
        print(f"bad trace: {e}", file=sys.stderr)
        return 1

    if args.check:
        problems = validate(events)
        if problems:
            print(f"FAIL: {len(problems)} structural problem(s):",
                  file=sys.stderr)
            for p in problems[:50]:
                print(f"  - {p}", file=sys.stderr)
            return 1
        print(f"check OK: {len(events)} events structurally valid")

    rep = report(events)
    print(json.dumps(rep, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rep, f, indent=2)

    frac = rep["overlap"]["overlap_frac"]
    if args.min_overlap is not None and not (frac >= args.min_overlap
                                             and not math.isnan(frac)):
        print(f"FAIL: overlap fraction {frac:.4f} < required "
              f"{args.min_overlap}", file=sys.stderr)
        return 2
    if args.max_overlap is not None and not (frac <= args.max_overlap):
        print(f"FAIL: overlap fraction {frac:.4f} > allowed "
              f"{args.max_overlap}", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
