"""Docs-smoke gate: every fenced ``repro.launch.serve`` command in the
README and ``docs/`` must actually run.

Extraction rules:

* only ```` ```bash ````-fenced blocks are scanned;
* backslash-continued lines are joined into one command;
* a command participates iff it invokes ``repro.launch.serve`` (other
  fenced commands — benchmarks, pytest, examples — have their own CI
  steps and stay untouched);
* each command gets quick-scale overrides appended (argparse last-wins,
  so ``--n-requests 12 --scale 0.05`` shrink any documented run to CI
  size without editing the docs).

A command that exits non-zero fails the gate with its output, so a
serving-API change that breaks a documented invocation fails here, not
on a reader's machine.

Run: ``PYTHONPATH=src python tools/docs_smoke.py [--list] [FILES...]``
"""
from __future__ import annotations

import argparse
import os
import re
import shlex
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_FILES = ["README.md", "docs/ARCHITECTURE.md", "docs/OPERATIONS.md",
                 "docs/OBSERVABILITY.md"]
QUICK_OVERRIDES = ["--n-requests", "12", "--scale", "0.05"]

_FENCE = re.compile(r"```bash\n(.*?)```", re.DOTALL)


def extract_commands(text: str) -> list:
    """Fenced-bash ``repro.launch.serve`` commands, continuations joined,
    in document order."""
    cmds = []
    for block in _FENCE.findall(text):
        joined = re.sub(r"\\\n\s*", " ", block)
        for line in joined.splitlines():
            line = line.strip()
            if line.startswith("#") or "repro.launch.serve" not in line:
                continue
            cmds.append(line)
    return cmds


def quick_command(cmd: str) -> list:
    """Split one documented command line into argv + quick overrides.

    ``--dump-spec`` runs exit before serving, and ``--dump-spec -``
    writes to stdout, so those keep their own (already instant) shape.
    """
    argv = shlex.split(cmd)
    # drop leading VAR=value env assignments (the docs spell out
    # PYTHONPATH=src; the runner injects it for every command)
    while argv and re.match(r"^\w+=", argv[0]):
        argv.pop(0)
    if "--dump-spec" in argv:
        return argv
    return argv + QUICK_OVERRIDES


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*", default=None,
                    help="markdown files to scan (default: README + docs/)")
    ap.add_argument("--list", action="store_true",
                    help="print the extracted commands without running")
    args = ap.parse_args()

    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        filter(None, [os.path.join(REPO, "src"), env.get("PYTHONPATH")]))

    failures = 0
    total = 0
    for rel in (args.files or DEFAULT_FILES):
        path = os.path.join(REPO, rel)
        with open(path) as f:
            cmds = extract_commands(f.read())
        for cmd in cmds:
            total += 1
            argv = quick_command(cmd)
            if args.list:
                print(f"{rel}: {' '.join(argv)}")
                continue
            print(f"[docs-smoke] {rel}: {cmd}", flush=True)
            # tmp files referenced by round-trip examples live in cwd
            proc = subprocess.run(argv, env=env, cwd=REPO,
                                  capture_output=True, text=True)
            if proc.returncode != 0:
                failures += 1
                print(f"FAILED (exit {proc.returncode}):\n{proc.stdout}"
                      f"\n{proc.stderr}", file=sys.stderr)
    if not args.list:
        print(f"[docs-smoke] {total - failures}/{total} documented "
              f"commands ran clean")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
