"""Metrics aggregation: TTFT/TBT/throughput definitions (paper §2)."""
import math

from repro.core.metrics import RequestMetrics, aggregate, percentile


def _req(rid, arrival, first, token_times, finish):
    m = RequestMetrics(rid, arrival, 10, len(token_times) + 1)
    m.first_token_time = first
    m.token_times = token_times
    m.finish_time = finish
    return m


def test_ttft_tbt():
    m = _req("a", 1.0, 1.5, [1.6, 1.8, 2.1], 2.1)
    assert math.isclose(m.ttft, 0.5)
    assert [round(x, 6) for x in m.tbts] == [0.1, 0.2, 0.3]


def test_aggregate():
    reqs = [_req("a", 0.0, 0.5, [0.6], 0.6),
            _req("b", 0.0, 1.0, [1.2], 1.2)]
    agg = aggregate(reqs)
    assert agg["completed"] == 2
    assert math.isclose(agg["throughput"], 2 / 1.2)
    assert agg["ttft_p99"] <= 1.0 and agg["ttft_p99"] >= 0.5
    assert math.isclose(agg["tbt_p50"], 0.15)


def test_percentile_edge_cases():
    assert math.isnan(percentile([], 99))
    assert percentile([3.0], 99) == 3.0


def test_aggregate_empty():
    agg = aggregate([])
    assert agg["completed"] == 0 and agg["throughput"] == 0.0
