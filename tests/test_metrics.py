"""Metrics aggregation: TTFT/TBT/throughput definitions (paper §2) and
SLO-attainment (goodput) for scheduler ablations."""
import math

from repro.core.metrics import (RequestMetrics, aggregate, meets_slo,
                                percentile, slo_attainment)


def _req(rid, arrival, first, token_times, finish):
    m = RequestMetrics(rid, arrival, 10, len(token_times) + 1)
    m.first_token_time = first
    m.token_times = token_times
    m.finish_time = finish
    return m


def test_ttft_tbt():
    m = _req("a", 1.0, 1.5, [1.6, 1.8, 2.1], 2.1)
    assert math.isclose(m.ttft, 0.5)
    assert [round(x, 6) for x in m.tbts] == [0.1, 0.2, 0.3]


def test_aggregate():
    reqs = [_req("a", 0.0, 0.5, [0.6], 0.6),
            _req("b", 0.0, 1.0, [1.2], 1.2)]
    agg = aggregate(reqs)
    assert agg["completed"] == 2
    assert math.isclose(agg["throughput"], 2 / 1.2)
    assert agg["ttft_p99"] <= 1.0 and agg["ttft_p99"] >= 0.5
    assert math.isclose(agg["tbt_p50"], 0.15)


def test_percentile_edge_cases():
    assert math.isnan(percentile([], 99))
    assert percentile([3.0], 99) == 3.0


def test_aggregate_empty():
    agg = aggregate([])
    assert agg["completed"] == 0 and agg["throughput"] == 0.0


def test_meets_slo():
    ok = _req("a", 0.0, 0.5, [0.6, 0.7], 0.7)          # ttft .5, tbts .1
    slow_start = _req("b", 0.0, 3.0, [3.1], 3.1)       # ttft 3.0
    choppy = _req("c", 0.0, 0.5, [2.5], 2.5)           # tbt 2.0
    unfinished = _req("d", 0.0, 0.5, [], None)
    assert meets_slo(ok, ttft_slo=1.0, tbt_slo=0.5)
    assert not meets_slo(slow_start, ttft_slo=1.0, tbt_slo=0.5)
    assert not meets_slo(choppy, ttft_slo=1.0, tbt_slo=0.5)
    assert not meets_slo(unfinished, ttft_slo=1.0, tbt_slo=0.5)


def test_slo_attainment_counts_unfinished_as_misses():
    reqs = [_req("a", 0.0, 0.5, [0.6], 0.6),
            _req("b", 0.0, 9.0, [9.1], 9.1),
            _req("c", 0.0, None, [], None)]
    assert math.isclose(slo_attainment(reqs, 1.0, 0.5), 1 / 3)
    assert math.isnan(slo_attainment([], 1.0, 0.5))


def test_aggregate_goodput_key_is_opt_in():
    reqs = [_req("a", 0.0, 0.5, [0.6], 0.6)]
    assert "goodput" not in aggregate(reqs)     # seed dict unchanged
    agg = aggregate(reqs, ttft_slo=1.0, tbt_slo=0.5)
    assert math.isclose(agg["goodput"], 1.0)
