"""Shared-prefix KV reuse: refcounted copy-on-write block cache.

Covers the allocator invariants (hypothesis property tests over random
share -> CoW -> free round-trips, with ``check_invariants`` asserting
refcount-consistent free-list accounting after every op), the engine path
(prefix hits skip prefill chunks and improve TTFT deterministically), the
prefix-affinity router, and the cache-off bit-identity guard (no new
metric keys, allocator behaviour unchanged)."""
import copy

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # not installed in the CI image; see _hypothesis_compat
    from _hypothesis_compat import given, settings, st

from repro.cluster import build_cluster, parse_cluster_spec
from repro.cluster.router import PrefixAffinityRouter
from repro.cluster.runtime import ClusterRuntime, WorkerEndpoint
from repro.configs import get_config
from repro.core.engine import Engine, EngineConfig
from repro.core.executor import NullExecutor
from repro.core.metrics import RequestMetrics, aggregate
from repro.core.request import Request
from repro.kvcache import BlockAllocator
from repro.serving.hardware import A10, DeviceModel
from repro.serving.trace import make_shared_prefix_trace

CFG = get_config("llama3-8b")

BS = 4
# three prefix families sharing sub-prefixes pairwise, so random traffic
# exercises full-block matches, mid-block divergence (CoW) and misses
_FAMILIES = [
    np.arange(0, 24, dtype=np.int32),                  # 6 full blocks
    np.concatenate([np.arange(0, 10, dtype=np.int32),  # diverges mid-block 2
                    np.arange(100, 114, dtype=np.int32)]),
    np.arange(1000, 1010, dtype=np.int32),             # 2.5 blocks, disjoint
]


def _tokens(fam: int, n_suffix: int, salt: int) -> np.ndarray:
    sfx = (np.arange(n_suffix, dtype=np.int32) + 10_000 + salt * 997) % 30000
    return np.concatenate([_FAMILIES[fam % len(_FAMILIES)], sfx])


# ---------------------------------------------------------------------------
# allocator invariants under share -> CoW -> free round-trips
# ---------------------------------------------------------------------------

@settings(max_examples=150, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["admit", "grow", "finish",
                                           "abort"]),
                          st.integers(0, 5), st.integers(0, 2),
                          st.integers(1, 60)),
                min_size=1, max_size=70))
def test_share_cow_free_roundtrips(ops):
    """The full prefix-cache lifecycle the engine drives: admit via
    ``share_blocks`` (refcount bumps + CoW on partial divergence), grow
    via ``extend_to``, then either register the sequence in the cache
    (finish) or drop it unregistered (abort/preempt). Refcount-consistent
    accounting must hold after every step."""
    a = BlockAllocator(num_blocks=48, block_size=BS, prefix_cache=True)
    live = {}
    salt = 0
    for op, rid_i, fam, n in ops:
        rid = f"r{rid_i}"
        if op == "admit" and rid not in live:
            salt += 1
            tokens = _tokens(fam, n, salt)
            shared = a.share_blocks(rid, tokens,
                                    max_tokens=len(tokens) - 1)
            assert 0 <= shared <= len(tokens) - 1
            # shared tokens really are a cached prefix: the index only
            # ever holds content previously registered via free()
            if a.can_extend_to(rid, len(tokens)):
                a.extend_to(rid, len(tokens))
                live[rid] = tokens
            else:
                a.free(rid)             # admission rollback, unregistered
        elif op == "grow" and rid in live:
            tokens = np.concatenate([live[rid],
                                     _tokens(fam, n, salt)[:n]])
            if a.can_extend_to(rid, len(tokens)):
                a.extend_to(rid, len(tokens))
                live[rid] = tokens
            else:                       # preemption-by-recompute
                a.free(rid)
                del live[rid]
        elif op == "finish" and rid in live:
            a.free(rid, cache_tokens=live.pop(rid))
        elif op == "abort" and rid in live:
            a.free(rid)
            del live[rid]
        a.check_invariants()
    for rid, tokens in live.items():
        a.free(rid, cache_tokens=tokens)
        a.check_invariants()
    # every block is free or retained-but-evictable: nothing leaked
    assert a.num_free == a.num_blocks


@settings(max_examples=100, deadline=None)
@given(st.integers(2, 64), st.integers(1, 40), st.integers(0, 30))
def test_share_matches_are_true_prefixes(n_prefix, n_a, n_b):
    """Whatever share_blocks claims as reused must be an exact token
    match against what was previously registered."""
    a = BlockAllocator(num_blocks=64, block_size=BS, prefix_cache=True)
    first = np.concatenate([np.arange(n_prefix, dtype=np.int32),
                            np.full(n_a, 7, np.int32)])
    a.allocate("r1", len(first))
    a.free("r1", cache_tokens=first)
    second = np.concatenate([np.arange(n_prefix, dtype=np.int32),
                             np.full(n_b, 9, np.int32)])
    shared = a.share_blocks("r2", second, max_tokens=len(second) - 1)
    assert np.array_equal(second[:shared], first[:shared])
    a.extend_to("r2", len(second))
    a.check_invariants()


# ---------------------------------------------------------------------------
# allocator unit behaviour
# ---------------------------------------------------------------------------

def test_full_and_partial_tail_reuse_with_cow():
    a = BlockAllocator(num_blocks=16, block_size=BS, prefix_cache=True)
    p1 = np.arange(10, dtype=np.int32)          # 2 full blocks + 2 partial
    a.allocate("r1", 10)
    a.free("r1", cache_tokens=p1)
    assert a.num_free == 16                      # cached blocks count free
    assert a.lookup_prefix(p1) == 10
    n = a.share_blocks("r2", p1, max_tokens=9)   # cap lands mid-partial
    assert n == 9
    assert a.n_cow_copies == 1                   # partial tail was copied
    a.check_invariants()


def test_mid_block_divergence_cow():
    a = BlockAllocator(num_blocks=16, block_size=BS, prefix_cache=True)
    p1 = np.arange(10, dtype=np.int32)
    a.allocate("r1", 10)
    a.free("r1", cache_tokens=p1)
    p2 = np.concatenate([p1[:6], np.int32([50, 51, 52, 53])])
    assert a.lookup_prefix(p2) == 6              # 1 full block + 2 in-block
    n = a.share_blocks("r3", p2)
    assert n == 6 and a.n_cow_copies == 1
    a.check_invariants()


def test_shared_blocks_are_refcounted_not_copied():
    a = BlockAllocator(num_blocks=16, block_size=BS, prefix_cache=True)
    p = np.arange(8, dtype=np.int32)             # exactly 2 full blocks
    a.allocate("r1", 8)
    a.free("r1", cache_tokens=p)
    used0 = a.num_blocks - len(a._free)
    a.share_blocks("r2", np.concatenate([p, p]), max_tokens=8)
    a.share_blocks("r3", np.concatenate([p, p]), max_tokens=8)
    # both requests reference the same two physical blocks
    assert a.block_table("r2") == a.block_table("r3")
    assert a.num_blocks - len(a._free) == used0
    a.check_invariants()
    a.free("r2")
    a.check_invariants()
    a.free("r3")
    a.check_invariants()
    assert a.num_free == a.num_blocks


def test_eviction_honors_free_block_signal():
    """The Balancer reads ``num_free`` (Alg. 1): cached refcount-0 blocks
    must count as free and allocation must reclaim them LRU-first."""
    a = BlockAllocator(num_blocks=4, block_size=BS, prefix_cache=True)
    a.allocate("x", 16)
    a.free("x", cache_tokens=np.arange(16, dtype=np.int32))
    assert a.num_free == 4
    assert a.can_allocate(16)
    a.allocate("y", 16)                          # must evict every block
    assert a.n_evictions == 4
    assert a.lookup_prefix(np.arange(16, dtype=np.int32)) == 0
    a.check_invariants()


def test_prefix_cache_off_is_bit_identical_allocator():
    """With caching off the allocator is the seed allocator: same free
    list order, no refcounts, free() returns blocks immediately."""
    a = BlockAllocator(num_blocks=8, block_size=BS)
    b = BlockAllocator(num_blocks=8, block_size=BS, prefix_cache=False)
    for alloc in (a, b):
        alloc.allocate("r", 10)
        assert alloc.share_blocks is not None    # API exists
        assert alloc.lookup_prefix(np.arange(10, dtype=np.int32)) == 0
        alloc.free("r", cache_tokens=np.arange(10, dtype=np.int32))
        assert alloc.num_free == 8
    assert a._free == b._free


# ---------------------------------------------------------------------------
# engine path: prefix hits skip prefill work
# ---------------------------------------------------------------------------

def _run_worker(reqs, cache: bool, max_slots: int = 4):
    eng = Engine("w", CFG,
                 EngineConfig(max_slots=max_slots, num_kv_blocks=4096,
                              prefix_cache=cache),
                 DeviceModel(A10, CFG), NullExecutor())
    rt = ClusterRuntime([WorkerEndpoint("w", eng, queue_cap=None)],
                        PrefixAffinityRouter())
    m = rt.run([copy.deepcopy(r) for r in reqs])
    return m, eng


def test_engine_prefix_hits_shorten_prefill_and_ttft():
    reqs = make_shared_prefix_trace(40, seed=0, interval=0.02,
                                    n_prefixes=2, prefix_len=512,
                                    mean_suffix_in=64, mean_out=16,
                                    max_out=32)
    m_off, _ = _run_worker(reqs, cache=False)
    m_on, eng = _run_worker(reqs, cache=True)
    assert m_on["completed"] == m_off["completed"] == len(reqs)
    assert m_on["prefill_tokens_saved"] > 0
    assert 0 < m_on["prefix_cache_hit_rate"] <= 1.5
    assert m_on["ttft_p99"] < m_off["ttft_p99"]
    assert eng.allocator.n_prefix_hits > 0
    eng.allocator.check_invariants()
    # the cache-off run's dict carries no cache keys (seed byte-identity)
    assert "prefill_tokens_saved" not in m_off
    assert "prefix_cache_hit_rate" not in m_off


def test_generated_tokens_enter_the_cache():
    """Multi-turn reuse: a follow-up whose prompt extends turn 1's full
    sequence (prompt + generated) reuses it from the cache."""
    eng = Engine("w", CFG,
                 EngineConfig(max_slots=2, num_kv_blocks=512,
                              prefix_cache=True, block_size=4),
                 DeviceModel(A10, CFG), NullExecutor())
    turn1 = Request(req_id="t1", prompt=np.arange(40, dtype=np.int32),
                    output_len=8)
    eng.add_request(turn1)
    for _ in range(200):
        if turn1.done:
            break
        eng.step()
    seq1 = np.concatenate([turn1.prompt,
                           np.asarray(turn1.generated, np.int32)])
    assert eng.allocator.lookup_prefix(seq1) == len(seq1)
    turn2 = Request(req_id="t2",
                    prompt=np.concatenate([seq1,
                                           np.arange(900, 912,
                                                     dtype=np.int32)]),
                    output_len=4)
    eng.add_request(turn2)
    for _ in range(200):
        if turn2.done:
            break
        eng.step()
    assert turn2.done
    assert turn2.metrics.cached_prefix_tokens >= len(seq1) - BS
    eng.allocator.check_invariants()


def test_cpi_handoff_shares_beyond_partial():
    """A Cronus handoff arrives mid-prompt (kv_payload covers the PPI's
    partial). When the CPI's cache holds a longer prefix, sharing must
    advance context past the partial — the chunked remainder shrinks."""
    eng = Engine("cpi", CFG,
                 EngineConfig(max_slots=2, num_kv_blocks=512,
                              prefix_cache=True, block_size=4),
                 DeviceModel(A10, CFG), NullExecutor())
    prompt = np.arange(64, dtype=np.int32)
    # warm the CPI cache with a finished request over the same prefix
    warm = Request(req_id="warm", prompt=prompt.copy(), output_len=2)
    eng.add_request(warm)
    for _ in range(100):
        if warm.done:
            break
        eng.step()
    # hand off a same-prefix request whose PPI partial covers 16 tokens
    hand = Request(req_id="h", prompt=np.concatenate(
        [prompt, np.arange(700, 708, dtype=np.int32)]), output_len=2)
    hand.partial_len = 16
    hand.context_len = 16
    hand.kv_payload = {"_null": 16}
    eng.add_request(hand)
    for _ in range(100):
        if hand.done:
            break
        eng.step()
    assert hand.done
    # shared well past the handed-off partial (cap: input_len - 1)
    assert hand.metrics.cached_prefix_tokens >= 64 - 16 - BS
    eng.allocator.check_invariants()


# ---------------------------------------------------------------------------
# metrics / aggregate
# ---------------------------------------------------------------------------

def test_aggregate_emits_cache_keys_only_on_hits():
    rm = RequestMetrics("r0", 0.0, 100, 4, first_token_time=1.0,
                        finish_time=2.0, token_times=[1.5, 2.0])
    base = aggregate([rm])
    assert "prefill_tokens_saved" not in base
    rm.cached_prefix_tokens = 64
    out = aggregate([rm])
    assert out["prefill_tokens_saved"] == 64
    assert out["prefix_cache_hit_rate"] == pytest.approx(0.64)
    # the shared keys are appended; the seed keys are untouched
    assert {k: v for k, v in out.items()
            if k not in ("prefill_tokens_saved",
                         "prefix_cache_hit_rate")} == base


# ---------------------------------------------------------------------------
# cluster wiring: DSL flag + prefix-affinity router
# ---------------------------------------------------------------------------

def test_dsl_cache_suffix_and_builder_threading():
    spec = parse_cluster_spec("2xworker:A10@sarathi@cache,cronus:A100+A10")
    assert spec.nodes[0].options == {"sched_policy": "sarathi",
                                     "prefix_cache": True}
    assert spec.nodes[1].options == {}
    system = build_cluster(CFG, spec)
    assert all(e.allocator.prefix_cache
               for ep in system.endpoints[:2] for e in ep.engines)
    assert not any(e.allocator.prefix_cache
                   for e in system.endpoints[2].engines)
    with pytest.raises(ValueError):
        parse_cluster_spec("worker:A10@bogus")


def test_prefix_affinity_routes_to_cached_endpoint():
    def worker(name):
        eng = Engine(name, CFG,
                     EngineConfig(max_slots=8, num_kv_blocks=1024,
                                  prefix_cache=True),
                     DeviceModel(A10, CFG), NullExecutor())
        return WorkerEndpoint(name, eng, queue_cap=None)

    a, b = worker("a"), worker("b")
    prompt = np.arange(64, dtype=np.int32)
    # warm b's cache with the prefix
    b.engine.allocator.allocate("seed", 64)
    b.engine.allocator.free("seed", cache_tokens=prompt)
    router = PrefixAffinityRouter()
    req = Request(req_id="r0", prompt=np.concatenate(
        [prompt, np.arange(500, 520, dtype=np.int32)]), output_len=4)
    assert router.select(req, [a, b]) is b
    # a cache-cold request falls back to least-loaded (most free blocks)
    cold = Request(req_id="r1",
                   prompt=np.arange(9000, 9064, dtype=np.int32),
                   output_len=4)
    assert router.select(cold, [a, b]) is not None


def test_prefix_affinity_respects_load_guard():
    def worker(name, cap=None):
        eng = Engine(name, CFG,
                     EngineConfig(max_slots=8, num_kv_blocks=1024,
                                  prefix_cache=True),
                     DeviceModel(A10, CFG), NullExecutor())
        return WorkerEndpoint(name, eng, queue_cap=cap)

    hot, cold = worker("hot"), worker("cold")
    prompt = np.arange(64, dtype=np.int32)
    hot.engine.allocator.allocate("seed", 64)
    hot.engine.allocator.free("seed", cache_tokens=prompt)
    for i in range(8):   # hot endpoint is deeply backed up
        hot.engine.add_request(Request(req_id=f"q{i}",
                                       prompt=np.zeros(8, np.int32),
                                       output_len=2))
    router = PrefixAffinityRouter(max_imbalance=4)
    req = Request(req_id="r0", prompt=np.concatenate(
        [prompt, np.arange(500, 520, dtype=np.int32)]), output_len=4)
    assert router.select(req, [hot, cold]) is cold


def test_cluster_end_to_end_under_prefix_affinity():
    reqs = make_shared_prefix_trace(60, seed=3, interval=0.05,
                                    n_prefixes=4, prefix_len=256,
                                    mean_suffix_in=64, mean_out=16,
                                    max_out=32)
    system = build_cluster(CFG, "2xworker:A10@cache",
                           router="prefix_affinity", max_slots=8)
    m = system.run([copy.deepcopy(r) for r in reqs])
    assert m["completed"] == len(reqs)
    assert m["prefill_tokens_saved"] > 0
    for e in system.engines:
        e.allocator.check_invariants()
