"""Training substrate: loss decreases on the structured synthetic corpus;
AdamW behaves; checkpoints roundtrip bit-exactly."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.training import (AdamWConfig, Trainer, adamw_update, init_adamw,
                            load_checkpoint, save_checkpoint)


def test_loss_decreases(tmp_path):
    cfg = get_config("llama3-8b", smoke=True)
    m = build_model(cfg)
    tr = Trainer(m, AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=60),
                 batch_size=8, seq_len=32)
    params, opt = tr.init()
    params, opt, losses = tr.run(params, opt, 30, log=None)
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])

    ck = str(tmp_path / "ckpt.npz")
    save_checkpoint(ck, params, opt, 30)
    p2, o2, step = load_checkpoint(ck, params, opt)
    assert step == 30
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_adamw_grad_clip_and_decay():
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.full((4, 4), 100.0)}   # huge grad -> clipped
    cfg = AdamWConfig(lr=0.1, grad_clip=1.0, weight_decay=0.0,
                      warmup_steps=0, total_steps=10)
    state = init_adamw(params)
    new_p, new_s, stats = adamw_update(cfg, params, grads, state)
    assert float(stats["grad_norm"]) > 1.0
    delta = np.abs(np.asarray(new_p["w"] - params["w"]))
    assert delta.max() < 0.2  # clip bounded the step
    assert int(new_s["step"]) == 1


def test_enc_dec_training_step():
    cfg = get_config("whisper-base", smoke=True)
    m = build_model(cfg)
    tr = Trainer(m, AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20),
                 batch_size=4, seq_len=16)
    params, opt = tr.init()
    params, opt, losses = tr.run(params, opt, 6, log=None)
    assert np.isfinite(losses).all()
