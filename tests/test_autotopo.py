"""Auto-topology planner: workload-spec round-trips, DSL
canonicalization, search-space enumeration + pruning, planner
determinism, the persistent evaluation memo (resume re-probes nothing),
ServeSpec.from_plan, seeded capacity-probe reproducibility, the
FLOPS-prior-vs-measured capacity tolerance, inventory edge cases, and
the opt-in per-endpoint utilization breakdown."""
import json

import pytest

from repro.autoscale import DeviceInventory, EndpointTemplate, UNIT_COST, \
    heuristic_capacity_qps
from repro.autotopo import (Candidate, EvalMemo, TopologyPlanner,
                            WorkloadSpec, enumerate_layouts, hand_baselines,
                            layout_cost_rate, node_templates, parse_workload,
                            plan_topology, router_choices, suffix_variants)
from repro.cluster import canonical_cluster_spec, parse_cluster_spec
from repro.serving.api import ServeSpec
from repro.serving.trace import make_trace
from repro.workloads import find_capacity, open_loop_measure

# cheap probe workload: 12 tiny requests per open-loop run — enough to
# exercise every planner code path in milliseconds per probe (capacity
# numbers are meaningless at this scale; determinism/plumbing tests
# don't read them)
QUICK = WorkloadSpec(n_requests=12, scale=0.05, target=0.8)
RACK = "A100:1,A10:1"


# ---------------------------------------------------------------------------
# WorkloadSpec
# ---------------------------------------------------------------------------

def test_workload_spec_round_trip():
    assert WorkloadSpec().spec == "azure:poisson"
    w = WorkloadSpec(trace="shared_prefix", arrival="burst", n_requests=40,
                     seed=3, scale=0.5, ttft_slo=2.0, tbt_slo=0.1,
                     target=0.8)
    assert parse_workload(w.spec) == w
    assert parse_workload(w) is w       # pass-through


def test_workload_spec_refusals():
    for bad in ("", "azure", "klingon:poisson", "azure:quantum",
                "azure:poisson:bogus=1", "azure:poisson:n=abc",
                "azure:poisson:n"):
        with pytest.raises(ValueError):
            parse_workload(bad)
    with pytest.raises(ValueError):
        WorkloadSpec(n_requests=0)
    with pytest.raises(ValueError):
        WorkloadSpec(scale=0.0)
    with pytest.raises(ValueError):
        WorkloadSpec(target=1.5)


def test_workload_arrival_specs_and_trace():
    w = WorkloadSpec(n_requests=8, scale=0.05)
    assert w.arrival_spec(2.5) == "poisson:2.5"
    assert WorkloadSpec(arrival="burst").arrival_spec(4.0) == "burst:4.0"
    assert WorkloadSpec(arrival="fixed").arrival_spec(4.0) == "fixed:0.25"
    with pytest.raises(ValueError):
        w.arrival_spec(0.0)
    reqs = w.make_requests(2.0)
    assert len(reqs) == 8
    sp = WorkloadSpec(trace="shared_prefix", n_requests=8, scale=0.05)
    assert sp.make_requests(2.0)[0].session is not None


# ---------------------------------------------------------------------------
# DSL canonicalization (tentpole dedupe foundation)
# ---------------------------------------------------------------------------

def test_canonical_cluster_spec_merges_and_sorts():
    # count grouping, node order and suffix spelling order all collapse
    assert canonical_cluster_spec("worker:A10,worker:A10") == "2xworker:A10"
    a = canonical_cluster_spec("worker:A10@cache@sarathi,cronus:A100+A10")
    b = canonical_cluster_spec("cronus:A100+A10,worker:A10@sarathi@cache")
    assert a == b == "cronus:A100+A10,worker:A10@sarathi@cache"
    # dp alias normalises to worker
    assert canonical_cluster_spec("dp:A10") == "worker:A10"
    # canonical output is a fixed point
    assert canonical_cluster_spec(a) == a
    # ClusterSpec objects are accepted too
    assert canonical_cluster_spec(parse_cluster_spec("2xworker:A10")) \
        == "2xworker:A10"


def test_parse_errors_report_segment_and_position():
    with pytest.raises(ValueError, match=r"segment 2 at char 11"):
        parse_cluster_spec("worker:A10,9q:A10")
    with pytest.raises(ValueError, match=r"segment 1 at char 0"):
        parse_cluster_spec("nonsense")
    # unknown suffix names itself and its segment in one line
    with pytest.raises(ValueError, match=r"@bogus") as ei:
        parse_cluster_spec("worker:A100,worker:A10@bogus")
    assert "segment 2" in str(ei.value)
    assert "\n" not in str(ei.value)
    # NodeSpec-level refusals (device arity, unknown device) carry the
    # position too, and keep the "bad node spec" phrasing the ServeSpec
    # refusal matrix documents
    with pytest.raises(ValueError, match=r"bad node spec in segment 1"):
        parse_cluster_spec("worker:A100+A10")


# ---------------------------------------------------------------------------
# search space: templates, enumeration, pruning
# ---------------------------------------------------------------------------

def test_node_templates_pair_asymmetry():
    inv = DeviceInventory.parse("A100:1,A10:2,A30:1")
    nodes = [n for n, _ in node_templates(inv)]
    # workers for every type; pairs only fast+slow, never inverted or
    # homogeneous (the PPI/CPI asymmetry pruning rule)
    assert "worker:A100" in nodes and "worker:A10" in nodes
    assert "cronus:A100+A10" in nodes and "cronus:A100+A30" in nodes
    assert "cronus:A30+A10" in nodes
    assert not any(n.startswith("cronus:A10+") for n in nodes)
    assert "cronus:A10+A100" not in nodes
    with pytest.raises(ValueError):
        node_templates(inv, pair_kinds=("bogus",))


def test_enumerate_layouts_prunes_and_dedupes():
    inv = DeviceInventory.parse("A100:1,A10:2")
    layouts = enumerate_layouts(inv, max_endpoints=3)
    assert layouts == sorted(layouts)              # deterministic order
    assert len(set(layouts)) == len(layouts)       # canonical dedupe
    assert "2xworker:A10,worker:A100" in layouts
    assert "cronus:A100+A10,worker:A10" in layouts
    assert "worker:A100" in layouts                # idle devices allowed
    # every layout is feasible and within the fan-out cap
    for layout in layouts:
        spec = parse_cluster_spec(layout)
        assert sum(n.count for n in spec.nodes) <= 3
        devs = [d for n in spec.nodes for _ in range(n.count)
                for d in n.devices]
        assert inv.can_build(devs)
    # full-rack restriction keeps only inventory-exhausting layouts
    full = enumerate_layouts(inv, max_endpoints=3, require_full_rack=True)
    assert set(full) <= set(layouts)
    assert all(len(parse_cluster_spec(f).nodes) >= 1 for f in full)
    for layout in full:
        devs = [d for n in parse_cluster_spec(layout).nodes
                for _ in range(n.count) for d in n.devices]
        assert sorted(devs) == ["A10", "A10", "A100"]


def test_router_and_suffix_variants():
    assert router_choices("worker:A100") == ("round_robin",)
    assert router_choices("2xworker:A10") == ("round_robin", "least_loaded")
    # affinity routers only offered when some node caches
    assert "prefix_affinity" not in router_choices(
        "2xworker:A10", ("least_loaded", "prefix_affinity"))
    assert "prefix_affinity" in router_choices(
        "2xworker:A10@cache", ("least_loaded", "prefix_affinity"))
    vs = suffix_variants("2xworker:A10", policies=("sarathi",), cache=True)
    assert "2xworker:A10@sarathi" in vs
    assert "2xworker:A10@cache" in vs
    assert "2xworker:A10@sarathi@cache" in vs
    assert "2xworker:A10" not in vs                # base never re-emitted
    with pytest.raises(ValueError):
        suffix_variants("worker:A10", policies=("bogus",))


def test_candidate_cost_is_ledger_priced():
    # DeviceLedger pricing: one second of the layout in A100-equivalents
    assert layout_cost_rate("worker:A100") == pytest.approx(1.0)
    assert layout_cost_rate("cronus:A100+A10") == pytest.approx(
        UNIT_COST["A100"] + UNIT_COST["A10"])
    c = Candidate("worker:A10,worker:A10", "least_loaded")
    assert c.cluster == "2xworker:A10"             # canonicalised on entry
    assert c.cost_rate == pytest.approx(2 * UNIT_COST["A10"])
    assert c.n_endpoints == 2
    with pytest.raises(ValueError):
        Candidate("worker:A10", "bogus_router")


def test_hand_baselines_consume_whole_rack():
    base = hand_baselines("A100:1,A10:2")
    assert base["workers"] == "2xworker:A10,worker:A100"
    assert base["pairs"] == "cronus:A100+A10,worker:A10"
    for layout in base.values():
        devs = [d for n in parse_cluster_spec(layout).nodes
                for _ in range(n.count) for d in n.devices]
        assert sorted(devs) == ["A10", "A10", "A100"]


# ---------------------------------------------------------------------------
# planner: determinism, memo, surfaces
# ---------------------------------------------------------------------------

def test_planner_deterministic_same_seed_same_plan():
    a = plan_topology(RACK, QUICK, max_endpoints=2)
    b = plan_topology(RACK, QUICK, max_endpoints=2)
    assert a.to_dict() == b.to_dict()
    assert a.ranked and a.best.cluster == b.best.cluster
    assert a.n_memo_hits == 0


def test_planner_memo_round_trips_and_resume_reprobes_nothing(tmp_path):
    memo = EvalMemo()
    first = plan_topology(RACK, QUICK, max_endpoints=2, memo=memo)
    assert first.n_evaluations > 0
    path = tmp_path / "memo.json"
    memo.save(str(path))
    reloaded = EvalMemo.load(str(path))
    assert len(reloaded) == len(memo)
    second = plan_topology(RACK, QUICK, max_endpoints=2, memo=reloaded)
    assert second.n_evaluations == 0               # zero completed re-probes
    assert second.n_memo_hits == first.n_evaluations
    assert [c.cluster for c in second.ranked] \
        == [c.cluster for c in first.ranked]
    assert [c.capacity_qps for c in second.ranked] \
        == [c.capacity_qps for c in first.ranked]


def test_planner_memo_key_includes_workload_and_bracket():
    memo = EvalMemo()
    plan_topology(RACK, QUICK, max_endpoints=2, memo=memo)
    # different workload: same layouts, no reuse
    other = WorkloadSpec(n_requests=12, scale=0.05, target=0.7)
    p2 = plan_topology(RACK, other, max_endpoints=2, memo=memo)
    assert p2.n_evaluations > 0
    # different probe bracket: no reuse either
    p3 = plan_topology(RACK, QUICK, max_endpoints=2, memo=memo,
                      probe_lo=0.5)
    assert p3.n_evaluations > 0


def test_planner_refuses_bad_inputs():
    with pytest.raises(ValueError):
        TopologyPlanner("", QUICK)                 # empty rack
    with pytest.raises(ValueError):
        TopologyPlanner("A10:0", QUICK)            # zero-count rack
    with pytest.raises(ValueError):
        TopologyPlanner(RACK, QUICK, beam_width=0)
    with pytest.raises(ValueError):
        TopologyPlanner(RACK, "azure:quantum")


def test_serve_spec_from_plan_round_trip():
    plan = plan_topology(RACK, QUICK, max_endpoints=2)
    spec = ServeSpec.from_plan(plan)
    assert spec.cluster == plan.best.cluster
    assert spec.router == plan.best.router
    if plan.best.capacity_qps > 0:
        assert spec.arrival == QUICK.arrival_spec(plan.best.capacity_qps)
    # plan JSON (the --plan-out artifact) builds the same spec
    assert ServeSpec.from_plan(
        json.loads(json.dumps(plan.to_dict()))) == spec
    # overrides win; bad ranks refuse
    assert ServeSpec.from_plan(plan, router="least_loaded").router \
        == "least_loaded"
    with pytest.raises(ValueError):
        ServeSpec.from_plan(plan, rank=99)
    service = spec.build()                         # the spec materialises
    assert service.endpoints


# ---------------------------------------------------------------------------
# seeded probes (satellite: same seed => same CapacityResult)
# ---------------------------------------------------------------------------

def test_find_capacity_same_seed_same_result():
    w = QUICK
    make_service = ServeSpec(cluster="worker:A100", router="round_robin").build

    def run_once():
        return find_capacity(make_service, w.make_requests, 0.25, 8.0,
                             target=w.target, ttft_slo=w.ttft_slo,
                             tbt_slo=w.tbt_slo, max_iters=3, seed=w.seed)
    a, b = run_once(), run_once()
    assert a == b                                  # frozen dataclass equality
    assert a.evaluations == b.evaluations


def test_open_loop_measure_seed_overrides_factory():
    seen = []

    def make_requests(rate, seed=None):
        seen.append(seed)
        return make_trace(6, seed=seed or 0, arrival=f"poisson:{rate!r}",
                          scale=0.05)
    spec = ServeSpec(cluster="worker:A100", router="round_robin")
    open_loop_measure(spec.build, make_requests, 2.0, seed=7)
    assert seen == [7]
    # without seed= the one-arg back-compat call is used
    open_loop_measure(spec.build, lambda rate: make_trace(
        6, arrival=f"poisson:{rate!r}", scale=0.05), 2.0)


# ---------------------------------------------------------------------------
# capacity seeding (satellite: prior vs measured, inventory edges)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_flops_prior_tracks_measured_capacity():
    # The FLOPS-proportional prior is calibrated against the committed
    # open-loop capacity of the cronus A100+A10 pair on the bursty
    # arrival model (benchmarks/baselines/BENCH_open_loop.json). The
    # documented tolerance is a factor of 2 either way: the prior only
    # has to order templates for probe brackets and scale-up choices,
    # not predict capacity — but drifting past 2x means _QPS_PER_TFLOP
    # needs recalibrating.
    spec = ServeSpec(approach="cronus")

    def make_requests(rate, seed=0):
        return make_trace(100, seed=seed, arrival=f"burst:{rate!r}:4:5")
    cap = find_capacity(spec.build, make_requests, 1.0, 24.0,
                        target=0.9, rel_tol=0.08, max_iters=4, seed=0)
    prior = heuristic_capacity_qps(("A100", "A10"))
    assert cap.sustainable
    assert 0.5 * cap.rate < prior < 2.0 * cap.rate


def test_inventory_edge_cases():
    # zero counts vanish on parse; the rack is empty but valid
    inv = DeviceInventory.parse("A10:0")
    assert inv.total == 0 and inv.spec == ""
    assert not inv.can_build(("A10",))
    with pytest.raises(ValueError):
        DeviceInventory.parse("B200:1")            # unknown device
    with pytest.raises(ValueError):
        DeviceInventory.parse("A10")               # missing count
    with pytest.raises(ValueError):
        DeviceInventory.parse("A10:x")             # non-integer count
    with pytest.raises(ValueError):
        DeviceInventory({"A10": -1})               # negative count
    # exhausted rack: take succeeds once, then refuses
    inv = DeviceInventory.parse("A100:1,A10:1")
    inv.take(("A100", "A10"))
    assert inv.total == 0
    with pytest.raises(ValueError):
        inv.take(("A10",))
    inv.put(("A10",))
    assert inv.counts == {"A10": 1}
    with pytest.raises(ValueError):
        inv.put(("B200",))
    # templates refuse nonsense capacities
    with pytest.raises(ValueError):
        EndpointTemplate("worker:A10", 0.0)


# ---------------------------------------------------------------------------
# utilization breakdown (satellite: opt-in, byte-identical when off)
# ---------------------------------------------------------------------------

def _run_cluster(**metrics_kw):
    spec = ServeSpec(cluster="worker:A100,worker:A10",
                     router="round_robin")
    service = spec.build()
    for r in make_trace(10, seed=0, interval=0.05, scale=0.05):
        service.submit(r)
    service.drain()
    return service.metrics(**metrics_kw)


def test_utilization_breakdown_opt_in():
    m = _run_cluster(utilization=True)
    util = m["utilization"]
    assert set(util) == {"worker0", "worker1"}
    for row in util.values():
        assert set(row) == {"busy_frac", "oldest_queued_age",
                            "dispatched", "completed"}
        assert 0.0 <= row["busy_frac"] <= 1.0
        assert row["oldest_queued_age"] >= 0.0
    # round-robin over 10 requests: 5 each, all completed
    assert [util[k]["dispatched"] for k in sorted(util)] == [5, 5]
    assert sum(r["completed"] for r in util.values()) == 10


def test_metrics_byte_identical_when_utilization_off():
    with_flag = _run_cluster(utilization=True)
    without = _run_cluster()
    assert "utilization" not in without
    with_flag.pop("utilization")
    assert json.dumps(with_flag, sort_keys=True) \
        == json.dumps(without, sort_keys=True)
