"""Pallas kernel validation (interpret mode) against pure-jnp oracles:
shape/dtype sweeps for the chunked-prefill flash kernel and the paged
decode kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.chunked_prefill_attention import chunked_prefill_attention_pallas
from repro.kernels.paged_attention import paged_decode_attention_pallas
from repro.kernels.ops import chunked_prefill_attention

KEY = jax.random.PRNGKey(0)


def _mk(b, c, h, kv, d, s, dtype):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, c, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d), dtype)
    ctx = jnp.arange(b) * 5 + 3
    q_pos = ctx[:, None] + jnp.arange(c)[None, :]
    kv_pos = jnp.where(jnp.arange(s)[None, :] < (ctx + c)[:, None],
                       jnp.arange(s)[None, :], -1)
    return q, k, v, q_pos.astype(jnp.int32), kv_pos.astype(jnp.int32)


@pytest.mark.parametrize("b,c,h,kv,d,s", [
    (1, 8, 4, 4, 32, 32),     # MHA
    (2, 16, 8, 2, 64, 64),    # GQA 4:1
    (2, 8, 8, 1, 64, 64),     # MQA
    (1, 32, 4, 4, 128, 32),   # d=128 MXU tile
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 8])
def test_chunked_prefill_kernel(b, c, h, kv, d, s, dtype, window):
    q, k, v, q_pos, kv_pos = _mk(b, c, h, kv, d, s, dtype)
    want = ref.chunked_prefill_attention_ref(q, k, v, q_pos, kv_pos, window)
    got = chunked_prefill_attention_pallas(
        q, k, v, q_pos, kv_pos, window=window, block_q=8, block_k=16,
        interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_chunked_prefill_ops_padding():
    """ops.py wrapper: unaligned C/S/D padded transparently."""
    q, k, v, q_pos, kv_pos = _mk(2, 13, 4, 2, 48, 50, jnp.float32)
    want = ref.chunked_prefill_attention_ref(q, k, v, q_pos, kv_pos, 0)
    got = chunked_prefill_attention(q, k, v, q_pos, kv_pos,
                                    use_pallas=True, block_q=8, block_k=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("b,h,kv,d,pages,page,maxp", [
    (2, 8, 2, 64, 16, 8, 4),
    (3, 4, 4, 32, 8, 16, 3),
    (1, 8, 1, 128, 32, 8, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_kernel(b, h, kv, d, pages, page, maxp, dtype):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    kp = jax.random.normal(ks[1], (pages, page, kv, d), dtype)
    vp = jax.random.normal(ks[2], (pages, page, kv, d), dtype)
    bt = jax.random.randint(ks[3], (b, maxp), 0, pages)
    cl = jnp.arange(b) * 7 % (maxp * page - 1) + 1
    want = ref.paged_decode_attention_ref(q, kp, vp, bt, cl.astype(jnp.int32))
    got = paged_decode_attention_pallas(q, kp, vp, bt, cl.astype(jnp.int32),
                                        interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_kernel_matches_model_attention():
    """The Pallas chunked-prefill kernel computes the same attention the
    model's jnp path uses in the engine (GQA + position masking)."""
    from repro.models.attention import gqa_attend, make_mask
    q, k, v, q_pos, kv_pos = _mk(2, 8, 8, 2, 64, 64, jnp.float32)
    mask = make_mask(q_pos, kv_pos, jnp.int32(0))
    want = gqa_attend(q, k, v, mask, 64 ** -0.5)
    got = chunked_prefill_attention_pallas(q, k, v, q_pos, kv_pos,
                                           block_q=8, block_k=16,
                                           interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("b,sq,h,kv,d,skv,window", [
    (2, 64, 8, 2, 32, 128, 0),
    (1, 100, 4, 4, 64, 100, 0),     # unaligned block boundaries
    (2, 64, 8, 2, 32, 128, 24),     # sliding window
    (1, 7, 2, 2, 16, 40, 0),        # chunk smaller than a block
])
def test_blocked_attention_matches_exact(b, sq, h, kv, d, skv, window):
    """Flash-style blocked attention (pure XLA, §Perf HC-prefill) must match
    the exact masked-softmax path bit-for-bit up to fp32 accumulation."""
    from repro.models.attention import (blocked_gqa_attend, gqa_attend,
                                        make_mask)
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, h, d))
    k = jax.random.normal(ks[1], (b, skv, kv, d))
    v = jax.random.normal(ks[2], (b, skv, kv, d))
    ctx = jnp.arange(b) * 3 + 5
    q_pos = (ctx[:, None] + jnp.arange(sq)[None, :]).astype(jnp.int32)
    kv_pos = jnp.where(jnp.arange(skv)[None, :] < (ctx + sq)[:, None],
                       jnp.arange(skv)[None, :], -1).astype(jnp.int32)
    want = gqa_attend(q, k, v, make_mask(q_pos, kv_pos, jnp.int32(window)),
                      d ** -0.5)
    got = blocked_gqa_attend(q, k, v, q_pos, kv_pos, jnp.int32(window),
                             d ** -0.5, block_q=16, block_k=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
