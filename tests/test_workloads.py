"""Open-loop workload subsystem: arrival-process statistics and
determinism, the trace-generator refactor's back-compat, OpenLoopDriver
equivalence with the closed-loop ``run(trace)`` replay, queueing-delay
metrics hygiene, and the capacity search."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving.api import ServeSpec
from repro.serving.hardware import A10, A100
from repro.serving.simulator import APPROACHES, run_approach
from repro.serving.trace import make_shared_prefix_trace, make_trace
from repro.workloads import (BurstyProcess, CapacityResult, DiurnalRamp,
                             FixedInterval, OpenLoopDriver, PoissonProcess,
                             capacity_search, open_loop_measure, parse_arrival,
                             rate_sweep)

CFG = get_config("llama3-8b")

PROCESSES = [FixedInterval(0.25), PoissonProcess(4.0),
             BurstyProcess(4.0, burstiness=3.0, mean_on=2.0),
             DiurnalRamp(2.0, 8.0, period=30.0)]


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("proc", PROCESSES, ids=lambda p: p.kind)
def test_arrivals_deterministic_per_seed(proc):
    a = proc.times(200, 7)
    b = proc.times(200, 7)
    assert np.array_equal(a, b)
    if proc.kind != "fixed":                 # fixed consumes no randomness
        assert not np.array_equal(a, proc.times(200, 8))


@pytest.mark.parametrize("proc", PROCESSES, ids=lambda p: p.kind)
def test_arrivals_monotone_nonnegative(proc):
    for seed in range(5):
        t = proc.times(500, seed)
        assert t.shape == (500,)
        assert t[0] >= 0.0
        assert np.all(np.diff(t) >= 0.0), f"negative gap (seed {seed})"


def test_poisson_interarrival_mean():
    t = PoissonProcess(5.0).times(20_000, 0)
    gaps = np.diff(t)
    assert abs(gaps.mean() - 0.2) < 0.01     # 5 qps -> mean gap 0.2 s
    # memorylessness sanity: gap variance ~ mean^2 for the exponential
    assert 0.8 < gaps.var() / gaps.mean() ** 2 < 1.2


def test_bursty_long_run_rate_and_degenerate():
    proc = BurstyProcess(5.0, burstiness=4.0, mean_on=2.0)
    t = proc.times(20_000, 3)
    rate = len(t) / t[-1]
    assert abs(rate - 5.0) / 5.0 < 0.1       # ON/OFF duty preserves the mean
    assert proc.mean_rate == 5.0
    # burstiness=1 collapses to plain Poisson (same rng consumption)
    assert np.array_equal(BurstyProcess(5.0, burstiness=1.0).times(100, 1),
                          PoissonProcess(5.0).times(100, 1))


def test_bursty_is_actually_bursty():
    """ON/OFF modulation must produce heavier short-window peaks than a
    Poisson stream of the same average rate."""
    bursty = BurstyProcess(4.0, burstiness=4.0, mean_on=2.0).times(8_000, 0)
    smooth = PoissonProcess(4.0).times(8_000, 0)

    def peak_window_count(times, w=1.0):
        counts = np.histogram(times, bins=np.arange(0, times[-1] + w, w))[0]
        return counts.max()
    assert peak_window_count(bursty) > 1.5 * peak_window_count(smooth)


def test_ramp_rate_within_band():
    proc = DiurnalRamp(2.0, 8.0, period=20.0)
    t = proc.times(10_000, 0)
    rate = len(t) / t[-1]
    assert 2.0 < rate < 8.0
    assert proc.mean_rate == 5.0


def test_parse_arrival_round_trip_and_errors():
    for proc in PROCESSES:
        again = parse_arrival(proc.spec)
        assert again == proc and again.spec == proc.spec
    assert parse_arrival(PROCESSES[1]) is PROCESSES[1]   # pass-through
    assert parse_arrival("burst:4").burstiness == 4.0    # defaults
    with pytest.raises(ValueError, match="unknown arrival process"):
        parse_arrival("warp:9")
    with pytest.raises(ValueError, match="non-numeric"):
        parse_arrival("poisson:fast")
    with pytest.raises(ValueError, match="parameter"):
        parse_arrival("poisson:1:2")
    with pytest.raises(ValueError, match="rate > 0"):
        parse_arrival("poisson:-3")
    with pytest.raises(ValueError, match="burstiness >= 1"):
        parse_arrival("burst:4:0.5")
    with pytest.raises(ValueError, match="rate_lo <= rate_hi"):
        parse_arrival("ramp:8:2")


# ---------------------------------------------------------------------------
# trace generator refactor (back-compat + arrival integration)
# ---------------------------------------------------------------------------

def test_interval_alias_byte_identical_to_seed_formula():
    trace = make_trace(40, seed=3, interval=0.25)
    assert [r.arrival for r in trace] == [i * 0.25 for i in range(40)]
    via_proc = make_trace(40, seed=3, arrival="fixed:0.25")
    for a, b in zip(trace, via_proc):
        assert np.array_equal(a.prompt, b.prompt)
        assert (a.output_len, a.arrival) == (b.output_len, b.arrival)


def test_arrival_model_never_changes_request_bodies():
    """Lengths/prompts draw from their own stream: switching the arrival
    process reshuffles timestamps only."""
    base = make_trace(40, seed=5, interval=0.0)
    for spec in ("poisson:4", "burst:4", "ramp:2:8"):
        alt = make_trace(40, seed=5, arrival=spec)
        arr = [r.arrival for r in alt]
        assert all(b >= a for a, b in zip(arr, arr[1:]))
        assert arr[0] > 0.0
        for a, b in zip(base, alt):
            assert np.array_equal(a.prompt, b.prompt)
            assert a.output_len == b.output_len


def test_shared_prefix_trace_takes_arrival():
    fixed = make_shared_prefix_trace(30, seed=1, interval=0.1)
    assert [r.arrival for r in fixed] == [i * 0.1 for i in range(30)]
    open_loop = make_shared_prefix_trace(30, seed=1, arrival="poisson:3")
    for a, b in zip(fixed, open_loop):
        assert np.array_equal(a.prompt, b.prompt)
        assert a.session == b.session


def test_interval_and_arrival_conflict():
    with pytest.raises(ValueError, match="not both"):
        make_trace(5, interval=0.5, arrival="poisson:2")


# ---------------------------------------------------------------------------
# OpenLoopDriver == closed loop on fixed-interval arrivals
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("approach", APPROACHES)
def test_driver_equals_closed_loop_all_at_t0(approach):
    """interval=0 is the fully degenerate case: every approach must
    reproduce the closed-loop aggregate dict exactly."""
    reqs = make_trace(40, seed=0, interval=0.0)
    legacy = run_approach(approach, CFG, A100, A10, reqs)
    got = OpenLoopDriver(ServeSpec(approach=approach).build()).run(reqs.fresh())
    assert got == legacy


@pytest.mark.parametrize("interval", [1 / 7.0, 0.4], ids=["near-sat", "slack"])
@pytest.mark.parametrize("approach", ["dp", "pp", "disagg_hl"])
def test_driver_equals_closed_loop_staggered(approach, interval):
    """Fixed-interval staggered arrivals: live submission reproduces the
    closed-loop metrics exactly for every approach whose dispatch-time
    decisions don't read cross-request load probes ahead of time. (cronus
    with the real Balancer and disagg_lh pre-book an idle PPI for *future*
    arrivals in the closed loop — stats pulled before the request would
    exist — which is precisely the foreknowledge open-loop measurement is
    built to remove, so exact equality is not asserted for them here;
    they are covered by the t0 case above.)"""
    reqs = make_trace(40, seed=0, interval=interval)
    legacy = run_approach(approach, CFG, A100, A10, reqs)
    got = OpenLoopDriver(ServeSpec(approach=approach).build()).run(reqs.fresh())
    assert got == legacy


def test_driver_refuses_unsorted_arrivals():
    reqs = make_trace(10, seed=0, interval=0.1)
    reqs[0], reqs[5] = reqs[5], reqs[0]
    driver = OpenLoopDriver(ServeSpec(approach="pp").build())
    with pytest.raises(ValueError, match="arrival-ordered"):
        driver.run(reqs)


# ---------------------------------------------------------------------------
# queueing-delay metrics
# ---------------------------------------------------------------------------

def test_queueing_keys_are_open_loop_only():
    reqs = make_trace(30, seed=0, interval=0.0)
    closed = ServeSpec(approach="pp").build().run(reqs.fresh())
    assert not any(k.startswith("queueing") for k in closed)
    assert "ttft_service_p99" not in closed

    driver = OpenLoopDriver(ServeSpec(approach="pp").build())
    base = driver.run(reqs.fresh())
    assert base == closed                    # run() itself stays bare
    m = driver.metrics()
    for key in ("queueing_p50", "queueing_p99", "ttft_service_p99"):
        assert key in m and np.isfinite(m[key])
    assert {k: v for k, v in m.items() if k in closed} == closed


def test_queueing_delay_decomposes_ttft():
    # 8 slots x 30 requests at t0: most requests wait for a slot
    driver = OpenLoopDriver(ServeSpec(approach="pp", max_slots=8).build())
    driver.run(make_trace(30, seed=1, interval=0.0))
    for h in driver.handles:
        m = h.request.metrics
        assert m.queueing_delay is not None and m.queueing_delay >= 0.0
        assert m.service_start_time >= m.arrival
        # first token can't precede first slot admission
        assert m.first_token_time >= m.service_start_time
    agg = driver.metrics()
    assert agg["queueing_p99"] > agg["queueing_p50"] >= 0.0


def test_queueing_separates_load_from_service():
    """Queueing delay is the load-dependent part of TTFT: near-zero when
    requests trickle in (bounded by one iteration of slot-admission
    alignment), dominant when everything lands at once."""
    light = OpenLoopDriver(ServeSpec(approach="pp", max_slots=8).build())
    light.run(make_trace(15, seed=2, interval=4.0))
    heavy = OpenLoopDriver(ServeSpec(approach="pp", max_slots=8).build())
    heavy.run(make_trace(15, seed=2, interval=0.0))
    ml, mh = light.metrics(), heavy.metrics()
    assert ml["queueing_p99"] < 0.1          # <= a couple iteration times
    assert mh["queueing_p99"] > 10 * ml["queueing_p99"]


# ---------------------------------------------------------------------------
# capacity search
# ---------------------------------------------------------------------------

def _step_goodput(threshold):
    return lambda rate: 1.0 if rate <= threshold else 0.0


def test_capacity_search_converges_to_boundary():
    res = capacity_search(_step_goodput(4.7), 1.0, 16.0,
                          target=0.9, rel_tol=0.02, max_iters=32)
    assert isinstance(res, CapacityResult) and res.sustainable
    assert res.rate <= 4.7                       # never overstates capacity
    assert 4.7 - res.rate <= 0.02 * 4.7 + 1e-9 or any(
        r > res.rate and g < 0.9 for r, g in res.evaluations)
    # every probe at or below the answer met the target (monotone model)
    assert all(g >= 0.9 for r, g in res.evaluations if r <= res.rate)


def test_capacity_search_monotone_in_threshold():
    """A strictly more capable system never searches to a lower capacity."""
    found = [capacity_search(_step_goodput(c), 0.5, 20.0,
                             rel_tol=0.02, max_iters=32).rate
             for c in (2.0, 5.0, 11.0)]
    assert found == sorted(found)
    assert all(f > 0 for f in found)


def test_capacity_search_brackets():
    assert capacity_search(_step_goodput(0.1), 1.0, 8.0).rate == 0.0
    assert not capacity_search(_step_goodput(0.1), 1.0, 8.0).sustainable
    assert capacity_search(_step_goodput(99.0), 1.0, 8.0).rate == 8.0
    with pytest.raises(ValueError, match="lo <= hi"):
        capacity_search(_step_goodput(1), 4.0, 2.0)
    with pytest.raises(ValueError, match="target"):
        capacity_search(_step_goodput(1), 1.0, 2.0, target=1.5)


def test_capacity_search_returns_measured_rate():
    evals = []

    def noisy(rate):
        evals.append(rate)
        return 1.0 if rate <= 6.0 else 0.0
    res = capacity_search(noisy, 1.0, 12.0, rel_tol=0.05, max_iters=8)
    assert res.rate in evals                     # never interpolated
    assert [r for r, _ in res.evaluations] == evals


# ---------------------------------------------------------------------------
# end-to-end sweep smoke (tiny, null executor)
# ---------------------------------------------------------------------------

def test_rate_sweep_end_to_end():
    def make_service():
        return ServeSpec(approach="pp").build()

    def make_requests(rate):
        return make_trace(20, seed=0, arrival=f"poisson:{rate:g}", scale=0.2)

    rows = rate_sweep(make_service, make_requests, [2.0, 20.0])
    assert [row["rate"] for row in rows] == [2.0, 20.0]
    for row in rows:
        assert row["completed"] == 20
        assert "queueing_p99" in row and "goodput" in row
    # heavier offered load can't reduce queueing on the same system
    assert rows[1]["queueing_p99"] >= rows[0]["queueing_p99"]


def test_open_loop_measure_goodput_counts_unfinished():
    m = open_loop_measure(
        lambda: ServeSpec(approach="pp").build(),
        lambda rate: make_trace(20, seed=0, arrival=f"poisson:{rate:g}",
                                scale=0.2),
        4.0, ttft_slo=5.0, tbt_slo=0.2)
    assert 0.0 <= m["goodput"] <= 1.0
    assert m["rate"] == 4.0
