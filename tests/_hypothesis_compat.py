"""Fallback for environments without ``hypothesis``.

The property tests in this suite use a small, fixed subset of the
hypothesis API (``given``/``settings`` plus the ``integers``,
``sampled_from``, ``lists`` and ``tuples`` strategies). When hypothesis is
installed the real library is used; otherwise this module provides a
deterministic miniature replacement: each ``@given`` test runs
``max_examples`` times over pseudo-random examples drawn from a RNG seeded
by the test's qualified name, so failures reproduce across runs and
machines. No shrinking, no database — just enough to keep the invariant
tests executable everywhere.

Usage in test modules::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

import random
import zlib


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class _Strategies:
    """Deterministic stand-ins for ``hypothesis.strategies``."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        seq = list(seq)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    @staticmethod
    def lists(elem: _Strategy, *, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elem.example(rng) for _ in range(n)]
        return _Strategy(draw)

    @staticmethod
    def tuples(*elems: _Strategy) -> _Strategy:
        return _Strategy(lambda rng: tuple(e.example(rng) for e in elems))


st = _Strategies()


def settings(max_examples: int = 10, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(*arg_strats: _Strategy, **kw_strats: _Strategy):
    def deco(fn):
        # plain zero-arg wrapper (not functools.wraps): pytest must see an
        # argument-free signature, or it would try to inject the strategy
        # parameters as fixtures
        def wrapper():
            n = getattr(wrapper, "_max_examples", 10)
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for _ in range(n):
                args = [s.example(rng) for s in arg_strats]
                kw = {k: s.example(rng) for k, s in kw_strats.items()}
                try:
                    fn(*args, **kw)
                except Exception as e:  # surface the failing example
                    raise AssertionError(
                        f"falsifying example (compat shim): args={args} "
                        f"kwargs={kw}") from e

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco
