"""Subprocess oracle check: Cronus / Disagg L-H / DP token streams must be
identical to a monolithic chunked-serving oracle, bit-for-bit.

Run in a FRESH process: within a long-lived pytest process, heap churn from
earlier tests perturbs XLA CPU fusion/alignment at the ULP level, flipping
greedy near-ties (diagnosed: schedules identical, logits differ ~1e-4).
A clean process is reproducibly deterministic (verified across dozens of
runs), making exact token equality a sound assertion here.

Exit 0 on success, 1 with a diff report on mismatch.
"""
import os
import sys

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_cpu_parallel_codegen_split_count=1")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import numpy as np   # noqa: E402
import jax           # noqa: E402

from repro.configs import get_config                       # noqa: E402
from repro.core.balancer import Balancer                   # noqa: E402
from repro.core.baselines import build_dp                  # noqa: E402
from repro.core.cronus import build_cronus, build_disaggregated  # noqa: E402
from repro.core.executor import RealExecutor               # noqa: E402
from repro.core.predictor import profile_chunked, profile_prefill  # noqa: E402
from repro.core.request import Request                     # noqa: E402
from repro.models import build_model                       # noqa: E402
from repro.serving.hardware import A100, A30, DeviceModel  # noqa: E402

S_KV, SLOTS, CHUNK = 128, 4, 16
LENS = [(17, 5), (33, 8), (9, 4), (41, 6), (25, 3)]


def main() -> int:
    cfg = get_config("llama3-8b", smoke=True)
    model = build_model(cfg, exact_moe=True)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(42)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n, _ in LENS]

    def oracle(prompt, out_len):
        ex = RealExecutor(model, params, max_slots=SLOTS, s_kv=S_KV,
                          chunk_pad=CHUNK)
        first, L = None, len(prompt)
        for lo_ in range(0, L, CHUNK):
            hi_ = min(lo_ + CHUNK, L)
            first = ex.prefill_chunk(0, prompt[lo_:hi_], lo_, hi_ == L)
        toks = [first]
        for t in range(out_len - 1):
            toks.append(ex.decode({0: toks[-1]}, {0: L + t})[0])
        return toks

    want = {f"r{i}": oracle(prompts[i], LENS[i][1]) for i in range(len(LENS))}
    hi, lo = DeviceModel(A100, cfg), DeviceModel(A30, cfg)

    def reqs():
        return [Request(req_id=f"r{i}", prompt=prompts[i].copy(),
                        output_len=LENS[i][1]) for i in range(len(LENS))]

    def factory(role):
        return RealExecutor(model, params, max_slots=SLOTS, s_kv=S_KV,
                            chunk_pad=CHUNK)

    failures = []

    # Cronus with the real Algorithm-1 balancer
    bal = Balancer(profile_prefill(lo), profile_chunked(hi))
    sys_c = build_cronus(cfg, lo, hi, executor_factory=factory, balancer=bal,
                         max_batched_tokens=16, max_slots=SLOTS, block_size=4)
    sys_c.run(reqs())
    for r in sys_c.cpi.finished:
        if r.generated != want[r.req_id]:
            failures.append(("cronus", r.req_id, r.generated, want[r.req_id]))
        if not (1 <= r.partial_len <= r.input_len):
            failures.append(("cronus-partial", r.req_id, r.partial_len))

    # Disaggregated L-H (partial length pinned to L_in)
    sys_d = build_disaggregated(cfg, lo, hi, executor_factory=factory,
                                max_batched_tokens=16, max_slots=SLOTS,
                                block_size=4)
    sys_d.run(reqs())
    for r in sys_d.cpi.finished:
        if r.generated != want[r.req_id]:
            failures.append(("disagg", r.req_id, r.generated, want[r.req_id]))
        if r.partial_len != r.input_len:
            failures.append(("disagg-partial", r.req_id, r.partial_len))

    # DP
    sys_dp = build_dp(cfg, hi, lo, executor_factory=factory,
                      max_slots=SLOTS, block_size=4)
    sys_dp.run(reqs())
    fin = {r.req_id: r for e in sys_dp.engines for r in e.finished}
    for rid, r in fin.items():
        if r.generated != want[rid]:
            failures.append(("dp", rid, r.generated, want[rid]))

    # MoE (boundary-pinned split) and attention-free SSM through Cronus
    for arch in ("kimi-k2-1t-a32b", "mamba2-780m"):
        n_reqs = 1 if arch.startswith("kimi") else 2
        acfg = get_config(arch, smoke=True)
        amodel = build_model(acfg, exact_moe=True)
        aparams = amodel.init_params(jax.random.PRNGKey(0))
        arng = np.random.default_rng(1)
        aprompts = [arng.integers(0, acfg.vocab_size, n).astype(np.int32)
                    for n in (19, 27)][:n_reqs]
        ex = RealExecutor(amodel, aparams, max_slots=SLOTS, s_kv=S_KV,
                          chunk_pad=CHUNK)
        awant = []
        for p in aprompts:
            ex.reset_slot(0)
            first = None
            for lo_ in range(0, len(p), CHUNK):
                hi_ = min(lo_ + CHUNK, len(p))
                first = ex.prefill_chunk(0, p[lo_:hi_], lo_, hi_ == len(p))
            toks = [first]
            for t in range(3):
                toks.append(ex.decode({0: toks[-1]}, {0: len(p) + t})[0])
            awant.append(toks)

        ahi, alo = DeviceModel(A100, acfg), DeviceModel(A30, acfg)

        class _Lp16:
            def partial_prefill_length(self, l_in, stats):
                return min(16, l_in)

        abal = (_Lp16() if arch.startswith("kimi")
                else Balancer(profile_prefill(alo), profile_chunked(ahi)))

        def afactory(role):
            return RealExecutor(amodel, aparams, max_slots=SLOTS, s_kv=S_KV,
                                chunk_pad=CHUNK)

        asys = build_cronus(acfg, alo, ahi, executor_factory=afactory,
                            balancer=abal, max_batched_tokens=16,
                            max_slots=SLOTS, block_size=4)
        areqs = [Request(req_id=f"r{i}", prompt=aprompts[i].copy(),
                         output_len=4) for i in range(n_reqs)]
        asys.run(areqs)
        got = {r.req_id: r.generated for r in asys.cpi.finished}
        for i in range(n_reqs):
            if got[f"r{i}"] != awant[i]:
                failures.append((arch, f"r{i}", got[f"r{i}"], awant[i]))

    if failures:
        for f in failures:
            print("MISMATCH:", f)
        return 1
    print("token-equivalence OK: cronus, disagg_lh, dp, moe, ssm == oracle")
    return 0


if __name__ == "__main__":
    sys.exit(main())
