"""Subprocess check: shard_map expert-parallel MoE dispatch (§Perf HC1-2)
matches the dense all-experts oracle on a real 2x2 device mesh."""
import os
import sys
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))
import jax
import jax.numpy as jnp
from repro.configs import get_config
from repro.models.moe import init_moe, moe_block, moe_block_dense_ref
from repro.models import sharding as shmod

cfg = get_config("kimi-k2-1t-a32b", smoke=True)  # E=4, top2
import dataclasses
cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # avoid drops for comparison
mesh = jax.make_mesh((2, 2), ("data", "model"))
rules = {"batch": ("data",), "experts": "model", "model": "model"}
p = init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model), jnp.float32)

want = moe_block_dense_ref(p, cfg, x)

shmod.set_rules(rules, mesh)
try:
    with mesh:
        fn = jax.jit(lambda p, x: moe_block(p, cfg, x, exact=False))
        got, aux = fn(p, x)
finally:
    shmod.set_rules(None)
err = float(jnp.max(jnp.abs(got - want)))
print("shard_map moe max err vs dense ref:", err)
assert err < 1e-3, err
print("OK")
