"""The online serving API: ServeSpec round-trips + validation, the
InferenceService submit/stream/cancel/drain surface, equality of the new
facade with the legacy ``system.run(trace)`` path, and the trace-aliasing
guard."""
import argparse
import dataclasses
import json
import os
import subprocess
import sys

import pytest

from repro.configs import get_config
from repro.core.request import ReqState, Request
from repro.serving.api import ServeSpec
from repro.serving.hardware import A10, A100
from repro.serving.simulator import APPROACHES, run_approach
from repro.serving.trace import Trace, make_trace

CFG = get_config("llama3-8b")


# ---------------------------------------------------------------------------
# ServeSpec: serialization + validation
# ---------------------------------------------------------------------------

def test_spec_json_roundtrip():
    spec = ServeSpec(approach="dp", hi="A100", lo="A30",
                     sched_policy="sarathi", prefix_cache=True,
                     max_slots=64, block_size=8)
    blob = json.dumps(spec.to_dict())
    assert ServeSpec.from_dict(json.loads(blob)) == spec


def test_spec_roundtrip_defaults_and_cluster():
    for spec in (ServeSpec(),
                 ServeSpec(cluster="2xcronus:A100+A10,4xworker:A10@sjf",
                           router="prefix_affinity")):
        assert ServeSpec.from_dict(json.loads(
            json.dumps(spec.to_dict()))) == spec


def test_spec_from_dict_rejects_unknown_keys():
    with pytest.raises(ValueError, match="unknown ServeSpec keys"):
        ServeSpec.from_dict({"approach": "cronus", "warp_factor": 9})


@pytest.mark.parametrize("kw,msg", [
    (dict(arch="gpt5"), "unknown arch"),
    (dict(approach="magic"), "unknown approach"),
    (dict(hi="H100"), "unknown device"),
    (dict(router="hash_ring"), "unknown router"),
    (dict(sched_policy="edf"), "unknown sched policy"),
    (dict(executor="cuda"), "unknown executor"),
    (dict(cluster="9q:A10"), "bad node spec"),
    (dict(executor="real", prefix_cache=True), "executor='paged'"),
    (dict(executor="real", cluster="2xworker:A10@cache"), "executor='paged'"),
    (dict(max_slots=0), "max_slots"),
    (dict(s_kv=0), "s_kv"),
    # dp/pp pin the paper's per-engine budgets; refuse a silently-ignored
    # override instead of pretending it applied
    (dict(approach="dp", max_batched_tokens=64), "fixed per-engine"),
    (dict(approach="pp", max_batched_tokens=64), "fixed per-engine"),
    (dict(arrival="warp:9"), "unknown arrival process"),
    (dict(arrival="poisson:-3"), "rate > 0"),
])
def test_spec_validation_errors(kw, msg):
    with pytest.raises(ValueError, match=msg):
        ServeSpec(**kw)


# ---------------------------------------------------------------------------
# CLI <-> spec (flag drift fails loudly here)
# ---------------------------------------------------------------------------

def test_cli_covers_every_spec_field():
    ap = argparse.ArgumentParser()
    ServeSpec.add_cli_args(ap)
    dests = {a.dest for a in ap._actions}
    for f in dataclasses.fields(ServeSpec):
        cli = {"executor": "real"}.get(f.name, f.name)
        assert cli in dests, f"ServeSpec.{f.name} has no CLI flag"


def test_from_cli_defaults_match_spec_defaults():
    ap = argparse.ArgumentParser()
    ServeSpec.add_cli_args(ap)
    assert ServeSpec.from_cli(ap.parse_args([])) == ServeSpec()


def test_from_cli_overrides_and_real_defaults():
    ap = argparse.ArgumentParser()
    ServeSpec.add_cli_args(ap)
    spec = ServeSpec.from_cli(ap.parse_args(
        ["--approach", "dp", "--sched-policy", "sarathi", "--prefix-cache",
         "--max-slots", "64"]))
    assert (spec.approach, spec.sched_policy, spec.prefix_cache,
            spec.max_slots) == ("dp", "sarathi", True, 64)
    real = ServeSpec.from_cli(ap.parse_args(["--real", "--smoke"]))
    # --real keeps the historical CPU-scale engine sizing
    assert (real.executor, real.max_slots, real.block_size) == ("real", 16, 4)
    open_loop = ServeSpec.from_cli(ap.parse_args(["--arrival", "poisson:6"]))
    assert open_loop.arrival == "poisson:6"
    with pytest.raises(ValueError, match="unknown arrival process"):
        ServeSpec.from_cli(ap.parse_args(["--arrival", "warp:9"]))


def test_serve_cli_smoke():
    """serve.py builds its system flags from ServeSpec.add_cli_args —
    --help exercising the full parser catches argparse-level drift."""
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--help"],
        capture_output=True, text=True, timeout=300, env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    for flag in ("--cluster", "--sched-policy", "--stream", "--cancel-after",
                 "--spec", "--dump-spec", "--arrival"):
        assert flag in proc.stdout
    # a missing spec file dies with a one-line message, not a traceback
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve",
         "--spec", "/nonexistent/deploy.json"],
        capture_output=True, text=True, timeout=300, env=env)
    assert proc.returncode != 0
    assert "bad serving spec" in proc.stderr
    assert "Traceback" not in proc.stderr


# ---------------------------------------------------------------------------
# submit-all + drain == legacy run (the bit-identity contract)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("interval", [0.0, 1 / 7.0],
                         ids=["maxtput", "staggered"])
@pytest.mark.parametrize("approach", APPROACHES)
def test_service_run_matches_legacy_run(approach, interval):
    reqs = make_trace(50, seed=0, interval=interval)
    legacy = run_approach(approach, CFG, A100, A10, reqs)
    service = ServeSpec(approach=approach).build()
    assert service.run(reqs.fresh()) == legacy


def test_cluster_service_matches_cluster_run():
    from repro.cluster import build_cluster
    spec = "cronus:A100+A10,2xworker:A10"
    reqs = make_trace(60, seed=2, interval=1 / 10.0)
    legacy = build_cluster(CFG, spec, router="least_loaded").run(reqs.fresh())
    service = ServeSpec(cluster=spec, router="least_loaded").build()
    assert service.run(reqs.fresh()) == legacy


def test_interleaved_step_until_matches_straight_drain():
    """Incremental stepping is just the batch loop sliced differently:
    step_until checkpoints must not change any metric."""
    reqs = make_trace(40, seed=5, interval=0.25)
    straight = ServeSpec(approach="cronus").build().run(reqs.fresh())
    service = ServeSpec(approach="cronus").build()
    for r in reqs.fresh():
        service.submit(r)
    for t in (1.0, 3.0, 5.0):
        assert service.step_until(t) >= t or service.n_active == 0
    assert service.drain() == straight


# ---------------------------------------------------------------------------
# streaming
# ---------------------------------------------------------------------------

def test_stream_yields_output_len_tokens_in_order():
    service = ServeSpec(approach="cronus").build()
    reqs = make_trace(6, seed=3, interval=0.5)
    handles = [service.submit(r) for r in reqs]
    streams = {h.req_id: list(h.tokens()) for h in handles}
    service.drain()
    for h in handles:
        toks = [tok for tok, _ in streams[h.req_id]]
        times = [t for _, t in streams[h.req_id]]
        assert len(toks) == h.request.output_len
        assert toks == h.request.generated
        assert times == sorted(times)
        assert h.done and h.status == "finished"
        # stream timestamps are the metric timestamps
        m = h.request.metrics
        assert times[0] == m.first_token_time
        assert times[1:] == m.token_times


def test_late_subscription_replays_full_history():
    """tokens() first asked after the request already generated: the
    stream still yields every token with its original timestamp."""
    service = ServeSpec(approach="cronus").build()
    reqs = make_trace(4, seed=11, interval=0.5)
    handles = [service.submit(r) for r in reqs]
    service.drain()                        # everything finished, unstreamed
    for h in handles:
        toks = list(h.tokens())
        assert [tok for tok, _ in toks] == h.request.generated
        m = h.request.metrics
        assert [t for _, t in toks] == [m.first_token_time] + m.token_times


def test_unstreamed_handles_buffer_no_tokens():
    """Batch replay must not retain per-token history (memory: a 1000-
    request trace is ~250k tokens) — buffering starts at subscription."""
    service = ServeSpec(approach="cronus").build()
    service.run(make_trace(5, seed=12, interval=0.0))
    assert all(not h._stream for h in service._handles.values())


def test_stream_works_on_disaggregated_first_token_at_ingest():
    # disagg delivers the first token with the KV transfer (TTFT fairness
    # rule) — the emission hook must still fire exactly once per token
    service = ServeSpec(approach="disagg_lh").build()
    reqs = make_trace(4, seed=7, interval=0.5)
    handles = [service.submit(r) for r in reqs]
    toks = list(handles[0].tokens())
    assert len(toks) == handles[0].request.output_len
    service.drain()
    for h in handles:
        assert len(h.request.generated) == h.request.output_len


# ---------------------------------------------------------------------------
# cancellation
# ---------------------------------------------------------------------------

def test_cancel_frees_kv_blocks_and_stays_out_of_aggregates():
    service = ServeSpec(cluster="worker:A10").build()
    reqs = make_trace(5, seed=4, interval=0.0)
    handles = [service.submit(r) for r in reqs]
    stream = handles[0].tokens()
    for _ in range(3):
        next(stream)                       # resident and decoding
    assert handles[0].cancel()
    eng = service.engines[0]
    assert eng.allocator.owned_blocks(reqs[0].req_id) == 0
    eng.allocator.check_invariants()
    assert handles[0].status == "cancelled"
    assert handles[0].request.metrics.cancel_time is not None
    assert not handles[0].cancel()         # idempotent: already terminal
    m = service.drain()
    assert m["completed"] == 4             # never in throughput aggregates
    assert m["cancelled"] == 1
    assert handles[0].request.metrics.finish_time is None
    # every block returned to the pool once the cluster drained
    assert eng.allocator.num_free == eng.allocator.num_blocks
    eng.allocator.check_invariants()


def test_cancel_before_dispatch():
    service = ServeSpec(cluster="worker:A10").build()
    reqs = make_trace(3, seed=9, interval=0.0)
    handles = [service.submit(r) for r in reqs]
    assert handles[2].cancel()             # still pending, never routed
    assert handles[2].status == "cancelled"
    m = service.drain()
    assert m["completed"] == 2 and m["cancelled"] == 1
    assert handles[2].request.state is ReqState.CANCELLED


def test_cancel_on_cronus_pair_mid_ppi():
    service = ServeSpec(approach="cronus").build()
    reqs = make_trace(5, seed=5, interval=0.0)
    handles = [service.submit(r) for r in reqs]
    service.step()
    service.step()                         # head requests are in the PPI
    assert handles[1].cancel()
    m = service.drain()
    assert m["completed"] == 4 and m["cancelled"] == 1
    for eng in service.engines:
        eng.allocator.check_invariants()
        assert eng.allocator.num_free == eng.allocator.num_blocks


def test_cancel_mid_decode_on_cronus_pair():
    service = ServeSpec(approach="cronus").build()
    reqs = make_trace(5, seed=6, interval=0.0)
    handles = [service.submit(r) for r in reqs]
    stream = handles[0].tokens()
    for _ in range(4):
        next(stream)                       # decoding on the CPI
    assert handles[0].cancel()
    m = service.drain()
    assert m["completed"] == 4 and m["cancelled"] == 1
    for eng in service.engines:
        eng.allocator.check_invariants()
        assert eng.allocator.num_free == eng.allocator.num_blocks


# ---------------------------------------------------------------------------
# the trace-aliasing guard
# ---------------------------------------------------------------------------

def test_replaying_same_requests_raises():
    reqs = make_trace(5, seed=8)
    first = ServeSpec(approach="cronus").build()
    first.run(reqs)
    second = ServeSpec(approach="cronus").build()
    with pytest.raises(ValueError, match="already replayed"):
        second.run(reqs)
    # legacy builder path refuses too (same shared loop)
    from repro.core.cronus import build_cronus
    from repro.core.executor import NullExecutor
    from repro.serving.hardware import DeviceModel
    sys_c = build_cronus(CFG, DeviceModel(A10, CFG), DeviceModel(A100, CFG),
                         executor_factory=lambda role: NullExecutor())
    with pytest.raises(ValueError, match="already replayed"):
        sys_c.run(reqs)


def test_trace_fresh_makes_reuse_safe():
    reqs = make_trace(5, seed=8)
    assert isinstance(reqs, Trace)
    a = ServeSpec(approach="cronus").build().run(reqs.fresh())
    b = ServeSpec(approach="cronus").build().run(reqs.fresh())
    assert a == b
    for r in reqs:                         # originals untouched
        assert r.state is ReqState.WAITING and not r.generated


def test_duplicate_submit_rejected():
    service = ServeSpec(approach="cronus").build()
    [r] = make_trace(1, seed=1)
    service.submit(r)
    with pytest.raises(ValueError, match="duplicate req_id"):
        service.submit(Request(req_id=r.req_id, prompt=r.prompt[:4],
                               output_len=2))
