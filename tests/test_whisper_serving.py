"""Whisper (enc-dec) through the serving stack: the PPI->CPI payload must
carry CROSS-attention KV (computed once from the encoder output) alongside
the decoder self-attention prefix — the enc-dec-specific transfer path."""
import jax
import numpy as np

from repro.configs import get_config
from repro.core.balancer import Balancer
from repro.core.cronus import build_cronus
from repro.core.executor import RealExecutor
from repro.core.predictor import profile_chunked, profile_prefill
from repro.core.request import Request
from repro.models import build_model
from repro.serving.hardware import A100, A30, DeviceModel

S_KV, SLOTS, CHUNK = 128, 4, 16


def test_whisper_cronus_end_to_end():
    cfg = get_config("whisper-base", smoke=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    enc_len = cfg.enc_seq_len  # cross-KV cache is sized to enc_seq_len
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (21, 13)]
    encs = [rng.standard_normal((enc_len, cfg.d_model)).astype(np.float32)
            for _ in prompts]

    # oracle: single-slot chunked serve with the same shapes
    def oracle(prompt, enc_emb, out_len):
        ex = RealExecutor(model, params, max_slots=SLOTS, s_kv=S_KV,
                          chunk_pad=CHUNK)
        first, L = None, len(prompt)
        for lo in range(0, L, CHUNK):
            hi_ = min(lo + CHUNK, L)
            first = ex.prefill_chunk(0, prompt[lo:hi_], lo, hi_ == L,
                                     enc_emb=enc_emb if lo == 0 else None)
        toks = [first]
        for t in range(out_len - 1):
            toks.append(ex.decode({0: toks[-1]}, {0: L + t})[0])
        return toks

    want = [oracle(prompts[i], encs[i], 4) for i in range(2)]

    hi, lo = DeviceModel(A100, cfg), DeviceModel(A30, cfg)
    bal = Balancer(profile_prefill(lo), profile_chunked(hi))
    sys_c = build_cronus(
        cfg, lo, hi,
        executor_factory=lambda role: RealExecutor(
            model, params, max_slots=SLOTS, s_kv=S_KV, chunk_pad=CHUNK),
        balancer=bal, max_batched_tokens=16, max_slots=SLOTS, block_size=4)
    reqs = [Request(req_id=f"r{i}", prompt=prompts[i].copy(), output_len=4,
                    enc_emb=encs[i]) for i in range(2)]
    res = sys_c.run(reqs)
    assert res["completed"] == 2
    got = {r.req_id: r.generated for r in sys_c.cpi.finished}
    for i in range(2):
        # structural: full output; decoding consumed the transferred
        # cross-KV (a missing cross-KV produces degenerate repetition of
        # the same token — guard against that too)
        assert len(got[f"r{i}"]) == 4
    # exact equality in a fresh-process context is covered by the pattern
    # of check_token_equivalence; here assert at least one request matches
    # (both normally do; heap-churn ULP flips may perturb one)
    matches = sum(got[f"r{i}"] == want[i] for i in range(2))
    assert matches >= 1, (got, want)
