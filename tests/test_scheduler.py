"""Iteration-level scheduler layer: policy semantics, skip-ahead admission,
dynamic paged-KV growth, preemption-by-recompute, and the Balancer-facing
stats fixes (all on NullExecutor — batch composition, not numerics)."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.engine import Engine, EngineConfig
from repro.core.executor import NullExecutor
from repro.core.request import ReqState, Request
from repro.scheduling import SCHEDULERS, make_scheduler
from repro.serving.hardware import A10, DeviceModel

CFG = get_config("llama3-8b")
DEV = DeviceModel(A10, CFG)


def _req(rid, input_len, output_len, arrival=0.0, ready=0.0):
    rng = np.random.default_rng(abs(hash(rid)) % 2**32)
    r = Request(req_id=rid,
                prompt=rng.integers(0, 100, input_len).astype(np.int32),
                output_len=output_len, arrival=arrival)
    r.ready_time = ready
    return r


def _engine(policy="fcfs", num_kv_blocks=4096, max_slots=8,
            max_batched_tokens=64, block_size=16, **ecfg_kw):
    return Engine(f"eng-{policy}", CFG,
                  EngineConfig(max_batched_tokens=max_batched_tokens,
                               max_slots=max_slots, block_size=block_size,
                               num_kv_blocks=num_kv_blocks,
                               sched_policy=policy, **ecfg_kw),
                  DEV, NullExecutor())


def _drain(eng, max_steps=100_000):
    steps = 0
    while (eng.runnable() or any(s is not None for s in eng.slots)) \
            and steps < max_steps:
        eng.step()
        steps += 1
    assert steps < max_steps, "engine did not drain"
    return steps


# ---------------------------------------------------------------------------
# policy plumbing
# ---------------------------------------------------------------------------

def test_registry_and_defaults():
    cfg = EngineConfig()
    for name in ("fcfs", "sarathi", "sjf", "priority"):
        assert name in SCHEDULERS
        make_scheduler(name, cfg)
    with pytest.raises(KeyError):
        make_scheduler("nope", cfg)
    assert not make_scheduler("fcfs", cfg).lazy_kv
    assert not make_scheduler("fcfs", cfg).skip_ahead
    assert make_scheduler("sarathi", cfg).lazy_kv
    assert make_scheduler("sarathi", cfg).skip_ahead
    # explicit EngineConfig knobs override the policy defaults
    assert make_scheduler("fcfs", EngineConfig(skip_ahead=True)).skip_ahead
    assert not make_scheduler(
        "sarathi", EngineConfig(lazy_kv=False)).lazy_kv


def test_fcfs_conservative_reservation():
    """fcfs (the seed policy) reserves input+output blocks at admission."""
    eng = _engine("fcfs", num_kv_blocks=64, block_size=16)
    eng.add_request(_req("a", 32, 16))
    eng.step()
    # ceil(48/16) = 3 blocks reserved although context is only 32 tokens
    assert eng.allocator.owned_blocks("a") == 3
    assert eng.n_preemptions == 0


def test_lazy_reservation_and_growth():
    """sarathi reserves the prompt only and extends as decode advances."""
    eng = _engine("sarathi", num_kv_blocks=64, block_size=16)
    eng.add_request(_req("a", 32, 40))
    eng.step()                       # prefill completes (budget 64 >= 32)
    assert eng.allocator.owned_blocks("a") == 3   # ceil(33/16), not ceil(72/16)
    for _ in range(20):
        eng.step()
    # decode grew the allocation dynamically
    assert eng.allocator.owned_blocks("a") > 3
    eng.allocator.check_invariants()


# ---------------------------------------------------------------------------
# satellite: skip-ahead admission (head-of-line blocking fix)
# ---------------------------------------------------------------------------

def test_hol_blocking_default_fcfs():
    """Seed semantics: a head still in transit blocks a ready follower."""
    eng = _engine("fcfs")
    eng.add_request(_req("head", 16, 2, ready=100.0))   # PPI->CPI transit
    eng.add_request(_req("tail", 16, 2, ready=0.0))
    assert not eng.runnable()
    assert eng.next_ready_time() == 100.0


def test_skip_ahead_admission():
    """With skip_ahead, the ready follower passes the blocked head."""
    eng = _engine("fcfs", skip_ahead=True)
    eng.add_request(_req("head", 16, 2, ready=100.0))
    eng.add_request(_req("tail", 16, 2, ready=0.0))
    assert eng.runnable()
    # the ready tail makes the engine runnable; only the in-transit head
    # remains a *future* wake-up time
    assert eng.next_ready_time() == 100.0
    eng.step()
    resident = [r.req_id for r in eng.slots if r]
    assert resident == ["tail"]
    assert eng.queue[0].req_id == "head"    # head keeps its turn


def test_skip_ahead_default_on_for_new_policies():
    eng = _engine("sarathi")
    eng.add_request(_req("head", 16, 2, ready=100.0))
    eng.add_request(_req("tail", 16, 2, ready=0.0))
    assert eng.runnable()


# ---------------------------------------------------------------------------
# satellite: stats() counts imminent decode load (TRANSFER ingest)
# ---------------------------------------------------------------------------

def test_stats_counts_transfer_as_imminent_decode():
    """A TRANSFER request whose context covers its prompt decodes this
    very iteration — the Balancer must see it, or it under-splits right
    after a handoff (regression: seed excluded them)."""
    eng = _engine("fcfs")
    r = _req("t", 32, 8)
    r.context_len = 32                 # fully prefilled on the PPI
    r.state = ReqState.TRANSFER
    r.slot = 0
    eng.slots[0] = r
    s = eng.stats()
    assert s.n_decode == 1
    assert s.decode_ctx_sum == float(r.total_ctx)
    # a TRANSFER still mid-prefill is imminent *prefill*, not decode
    r2 = _req("p", 32, 8)
    r2.context_len = 16
    r2.state = ReqState.TRANSFER
    r2.slot = 1
    eng.slots[1] = r2
    assert eng.stats().n_decode == 1


def test_stats_counts_delivered_handoffs_in_queue():
    """The live path of the same undercount: a PPI->CPI handoff delivered
    into the queue (ready, fully prefilled) is admitted and decoding
    within the next iteration — it is imminent decode load. Counted only
    under lazy (honest-accounting) policies; fcfs keeps the seed's exact
    Balancer signal (the bit-identity contract)."""
    eng = _engine("sarathi")
    eng.clock = 5.0
    ready = _req("h", 32, 8, ready=4.0)
    ready.context_len = 32             # full context arrived with it
    eng.add_request(ready)
    in_transit = _req("x", 32, 8, ready=9.0)
    in_transit.context_len = 32        # same shape but not ready yet
    eng.add_request(in_transit)
    fresh = _req("f", 32, 8, ready=0.0)   # ready but needs local prefill
    eng.add_request(fresh)
    s = eng.stats()
    assert s.n_decode == 1             # only the ready, prefilled handoff
    assert s.decode_ctx_sum == float(ready.total_ctx)
    # fcfs (seed signal, bit-identity contract) ignores the queue
    eng_f = _engine("fcfs")
    eng_f.clock = 5.0
    r = _req("h2", 32, 8, ready=4.0)
    r.context_len = 32
    eng_f.add_request(r)
    assert eng_f.stats().n_decode == 0


# ---------------------------------------------------------------------------
# tentpole: multi-sequence chunk packing
# ---------------------------------------------------------------------------

def test_fcfs_single_prefill_per_iteration():
    eng = _engine("fcfs", max_batched_tokens=64)
    for i in range(3):
        eng.add_request(_req(f"r{i}", 16, 2))
    eng.step()
    advanced = [r for r in eng.slots if r and r.context_len > 0]
    assert len(advanced) == 1          # head chunk only, as the seed


def test_sarathi_packs_multiple_prefills():
    eng = _engine("sarathi", max_batched_tokens=64)
    for i in range(3):
        eng.add_request(_req(f"r{i}", 16, 2))
    eng.step()
    advanced = [r for r in eng.slots if r and r.context_len > 0]
    assert len(advanced) == 3          # 3 x 16 tokens packed into B=64


def test_sjf_orders_by_remaining_work():
    eng = _engine("sjf", max_batched_tokens=32)
    eng.add_request(_req("long", 128, 32))
    eng.add_request(_req("short", 16, 2))
    eng.step()
    short = next(r for r in eng.slots if r and r.req_id == "short")
    longr = next(r for r in eng.slots if r and r.req_id == "long")
    # the short job claimed the budget first
    assert short.context_len == 16
    assert longr.context_len == 32 - 16


# ---------------------------------------------------------------------------
# tentpole: dynamic growth admits more + preemption-by-recompute
# ---------------------------------------------------------------------------

def test_lazy_growth_admits_more_concurrency():
    """Acceptance: a long-output workload that refuses admission under
    conservative reservation admits more concurrent requests lazily."""
    # pool: 16 blocks = 256 tokens; each request needs 32+210=242 tokens
    # conservatively (15 blocks) -> fcfs can only ever hold ONE resident
    reqs = [(f"r{i}", 32, 210) for i in range(4)]

    def max_concurrency(policy):
        eng = _engine(policy, num_kv_blocks=16, block_size=16,
                      max_batched_tokens=64)
        for rid, i, o in reqs:
            eng.add_request(_req(rid, i, o))
        peak = 0
        for _ in range(100_000):
            if not eng.runnable():
                break
            eng.step()
            peak = max(peak, sum(1 for s in eng.slots if s is not None))
        return peak, eng

    peak_fcfs, eng_f = max_concurrency("fcfs")
    peak_lazy, eng_l = max_concurrency("sarathi")
    assert peak_fcfs == 1
    assert peak_lazy > 1
    assert len(eng_f.finished) == len(reqs)
    assert len(eng_l.finished) == len(reqs)


def test_preemption_by_recompute():
    """Decode growth past the pool preempts victims (recompute) and every
    request still completes with its full token count."""
    eng = _engine("sarathi", num_kv_blocks=12, block_size=16,
                  max_batched_tokens=64)
    outs = {}
    for i in range(4):
        r = _req(f"r{i}", 24, 48)
        outs[r.req_id] = r.output_len
        eng.add_request(r)
    _drain(eng)
    assert eng.n_preemptions > 0, "preemption path was not exercised"
    assert len(eng.finished) == 4
    for r in eng.finished:
        # output_len shrinks when generated tokens fold into the prompt at
        # preemption; the metrics object records the original contract
        total_tokens = 1 + len(r.metrics.token_times)
        assert total_tokens == outs[r.req_id], r.req_id
        assert r.metrics.finish_time is not None
        ts = [r.metrics.first_token_time] + r.metrics.token_times
        assert all(b >= a for a, b in zip(ts, ts[1:]))
    eng.allocator.check_invariants()
    assert eng.allocator.num_free == eng.allocator.num_blocks


def test_preempted_request_folds_generated_into_prompt():
    eng = _engine("sarathi", num_kv_blocks=8, block_size=8,
                  max_batched_tokens=32)
    a = _req("a", 16, 40)
    b = _req("b", 16, 40)
    eng.add_request(a)
    eng.add_request(b)
    _drain(eng)
    assert eng.n_preemptions > 0
    victim = next(r for r in eng.finished if r.preempted)
    # prompt grew by the tokens generated before preemption, and the
    # output contract shrank by the same amount
    assert victim.input_len > 16
    assert victim.input_len - 16 == 40 - victim.output_len


def test_lazy_refuses_infeasible_request_instead_of_crashing():
    """A request whose final context can never fit the whole pool must be
    refused at admission (the conservative policies' stall semantics), not
    admitted lazily only to OOM mid-decode with no victim left
    (regression: extend_to raised MemoryError and killed the run)."""
    eng = _engine("sarathi", num_kv_blocks=64, block_size=16,
                  max_batched_tokens=512, max_slots=256)
    big = _req("big", 192, 2048)       # 2240 tokens > 1024-token pool
    ok = _req("ok", 64, 32)
    eng.add_request(big)
    eng.add_request(ok)
    _drain(eng)                        # must not raise MemoryError
    assert len(eng.finished) == 1      # the feasible request completed
    assert eng.finished[0].req_id == "ok"
    assert eng.queue[0].req_id == "big"    # refused, still queued
    assert not eng.runnable()


def test_single_token_handoff_finishes_at_ingest():
    """A fully-prefilled handoff whose output is complete after the
    ingest-appended first token (output_len == 1) must finish cleanly
    (regression: it stayed in the decode batch with a freed slot and
    step() crashed on new_tokens[None]; pre-existing at the seed)."""
    eng = _engine("fcfs")
    r = _req("one", 32, 1)
    r.context_len = 32
    r.kv_payload = {"_null": 32}
    r.first_token = 7
    eng.add_request(r)
    eng.step()
    assert len(eng.finished) == 1
    done = eng.finished[0]
    assert done.generated == [7]
    assert done.metrics.first_token_time is not None
    assert done.metrics.finish_time == done.metrics.first_token_time
    # the KV transfer is charged before the token counts (fairness rule)
    assert done.metrics.first_token_time > 0.0
    assert eng.allocator.num_free == eng.allocator.num_blocks


def test_infeasible_request_does_not_livelock_cluster():
    """A permanently refused request must not freeze the cluster loop:
    the idle-jump reads next_ready_time, which must ignore ready-but-
    inadmissible requests (their past timestamp made the jump a no-op and
    the loop spun for max_steps, starving feasible traffic)."""
    from repro.cluster.router import RoundRobinRouter
    from repro.cluster.runtime import ClusterRuntime, WorkerEndpoint
    eng = _engine("sarathi", num_kv_blocks=10, block_size=16,
                  max_batched_tokens=64)
    big = _req("big", 100, 100, arrival=0.0)   # 200 > 160-token pool
    ok = _req("ok", 32, 8, arrival=5.0)
    ok.metrics.arrival = 5.0
    runtime = ClusterRuntime([WorkerEndpoint("w", eng, queue_cap=None)],
                             RoundRobinRouter())
    m = runtime.run([big, ok], max_steps=50_000)
    assert m["completed"] == 1                 # ok served, no spin
    assert eng.next_ready_time() is None       # refused head reports nothing


def test_stale_ppi_timestamp_not_kept_as_ttft():
    """A request preempted mid-prefill before emitting any token must get
    its TTFT from the eventual completion, not from a stale timestamp a
    PPI wrote into the shared metrics object (regression: the recompute
    guard kept the pre-delivery timestamp, understating TTFT for exactly
    the preempting policies under comparison)."""
    eng = _engine("sarathi", num_kv_blocks=11, block_size=16,
                  max_batched_tokens=4)
    a = _req("a", 16, 64)
    b = _req("b", 120, 4)
    b.metrics.first_token_time = 1e-4   # PPI-side internal timestamp
    eng.add_request(a)
    eng.add_request(b)
    _drain(eng)
    assert b.preempted
    assert b.metrics.first_token_time > 1e-4   # overwritten at delivery
    assert 1 + len(b.metrics.token_times) == 4  # full output accounted


def test_growth_preempts_midprefill_resident():
    """The sole decoder's KV growth must be able to evict a mid-prefill
    resident holding the remaining blocks (regression: with only RUNNING
    victims considered, extend_to raised MemoryError here)."""
    eng = _engine("sarathi", num_kv_blocks=11, block_size=16,
                  max_batched_tokens=4)
    a = _req("a", 16, 64, arrival=0.0)    # becomes the sole decoder
    b = _req("b", 120, 4, arrival=0.0)    # slow prefill holds 8 blocks
    eng.add_request(a)
    eng.add_request(b)
    _drain(eng)                            # must not raise MemoryError
    assert eng.n_preemptions > 0
    assert b.preempted                     # evicted while still prefilling
    assert len(eng.finished) == 2
    eng.allocator.check_invariants()


def test_deterministic_replay():
    """Same policy + same trace -> identical run, including preemptions."""
    def one(policy):
        eng = _engine(policy, num_kv_blocks=12, block_size=16,
                      max_batched_tokens=64)
        for i in range(4):
            eng.add_request(_req(f"r{i}", 24, 48))
        _drain(eng)
        return (eng.n_preemptions, eng.clock,
                [(r.req_id, r.metrics.finish_time) for r in eng.finished])

    assert one("sarathi") == one("sarathi")
    assert one("sjf") == one("sjf")


# ---------------------------------------------------------------------------
# policy threading: cluster DSL / builders
# ---------------------------------------------------------------------------

def test_cluster_dsl_policy_suffix():
    from repro.cluster import build_cluster, parse_cluster_spec
    spec = parse_cluster_spec("cronus:A100+A10@sarathi,2xworker:A10@sjf")
    assert spec.nodes[0].options["sched_policy"] == "sarathi"
    assert spec.nodes[1].options["sched_policy"] == "sjf"
    with pytest.raises(ValueError):
        parse_cluster_spec("worker:A10@bogus")
    system = build_cluster(CFG, spec)
    assert system.endpoints[0].sched_policy == "sarathi"
    assert system.endpoints[0].cpi.ecfg.sched_policy == "sarathi"
    assert system.endpoints[1].sched_policy == "sjf"
    assert system.endpoints[1].engine.ecfg.sched_policy == "sjf"
    # cluster-wide default fills nodes without a suffix
    system2 = build_cluster(CFG, "worker:A10", sched_policy="sarathi")
    assert system2.endpoints[0].sched_policy == "sarathi"


def test_build_system_threads_policy():
    from repro.serving.simulator import build_system
    sys_c = build_system("cronus", CFG, A10, A10, sched_policy="sjf")
    assert sys_c.cpi.ecfg.sched_policy == "sjf"
    assert sys_c.ppi.ecfg.sched_policy == "sjf"


def test_policies_through_cronus_pair_with_offload():
    """The riskiest composition: Balancer pair + bounded decode offload +
    lazy policies. Tiny KV pools force Alg. 1 fallback, offloaded decoders
    on the prefill-only PPI, and CPI preemptions — everything must still
    complete with exact token-timestamp counts."""
    from repro.core.balancer import Balancer
    from repro.core.cronus import build_cronus
    from repro.core.predictor import profile_chunked, profile_prefill
    from repro.serving.hardware import A100
    hi, lo = DeviceModel(A100, CFG), DEV
    for policy in ("fcfs", "sarathi", "sjf"):
        bal = Balancer(profile_prefill(lo), profile_chunked(hi))
        sys_c = build_cronus(CFG, lo, hi,
                             executor_factory=lambda role: NullExecutor(),
                             balancer=bal, max_batched_tokens=64,
                             max_slots=8, block_size=4,
                             decode_offload=True, sched_policy=policy)
        for eng, blocks in ((sys_c.cpi, 40), (sys_c.ppi, 60)):
            eng.allocator = type(eng.allocator)(num_blocks=blocks,
                                                block_size=4)
            eng.ecfg.num_kv_blocks = blocks
        reqs = [_req(f"r{i}", 20 + i % 13, 30) for i in range(12)]
        res = sys_c.run(reqs)
        assert res["completed"] == 12, policy
        if policy != "fcfs":
            assert sys_c.cpi.n_preemptions > 0, policy
        for eng in (sys_c.ppi, sys_c.cpi):
            eng.allocator.check_invariants()
            for r in eng.finished:
                assert 1 + len(r.metrics.token_times) == 30, (policy, r.req_id)


def test_policy_end_to_end_small_trace():
    """All policies complete a small mixed trace through the cluster
    runtime (worker endpoint) with consistent metrics."""
    from repro.cluster.router import RoundRobinRouter
    from repro.cluster.runtime import ClusterRuntime, WorkerEndpoint
    for policy in ("fcfs", "sarathi", "sjf"):
        eng = _engine(policy, num_kv_blocks=256, max_slots=16,
                      max_batched_tokens=128)
        reqs = [_req(f"q{i}", 8 * (i % 5 + 1), 4 + i % 7, arrival=0.1 * i)
                for i in range(12)]
        for r in reqs:
            r.metrics.arrival = r.arrival
        runtime = ClusterRuntime(
            [WorkerEndpoint("w", eng, queue_cap=None)], RoundRobinRouter())
        m = runtime.run(reqs)
        assert m["completed"] == 12, policy
        assert m["throughput"] > 0, policy
