"""Eq. 2 / Eq. 3 predictors: linear fits on roofline-profiled data reach the
paper's fit quality (paper: R2=0.993 / MAPE 7.4% for prefill on A30;
R2=0.990 / MAPE 0.8% for chunked iterations on A100 — Fig. 3)."""
import numpy as np

from repro.configs import get_config
from repro.core.predictor import (ChunkedIterPredictor, PrefillPredictor,
                                  profile_chunked, profile_prefill)
from repro.serving.hardware import A100, A30, DeviceModel

CFG = get_config("llama3-8b")


def test_prefill_fit_quality():
    pred = profile_prefill(DeviceModel(A30, CFG))
    assert pred.r2 > 0.95, pred.r2   # paper: 0.993, MAPE 7.4% (A30)
    assert pred.mape < 0.15, pred.mape
    # slope positive; intercept may be slightly negative (the roofline
    # max(compute, memory) kink) — bounded near zero
    assert pred.k_p > 0 and pred.b_p > -0.05


def test_chunked_fit_quality():
    pred = profile_chunked(DeviceModel(A100, CFG))
    assert pred.r2 > 0.95, pred.r2   # paper: 0.990, MAPE 0.8% (A100, Fig 3)
    assert pred.mape < 0.05, pred.mape
    # prefill-context slope positive; the decode-context slope may be ~0 on
    # a compute-bound device (decodes displace prefill tokens in the budget)
    assert pred.k_ctxp > 0 and pred.k_ctxd > -1e-7


def test_fit_recovers_exact_linear():
    xs = np.linspace(10, 1000, 50)
    pred = PrefillPredictor().fit(xs, 0.003 * xs + 0.2)
    assert abs(pred.k_p - 0.003) < 1e-9 and abs(pred.b_p - 0.2) < 1e-9
    assert pred.r2 > 0.999999

    x1 = np.tile(np.linspace(0, 5000, 20), 10)
    x2 = np.repeat(np.linspace(0, 9000, 10), 20)
    pred2 = ChunkedIterPredictor().fit(x1, x2, 1e-5 * x1 + 2e-6 * x2 + 0.01)
    assert abs(pred2.k_ctxp - 1e-5) < 1e-12
    assert abs(pred2.k_ctxd - 2e-6) < 1e-12


def test_predict_monotone():
    pred = profile_prefill(DeviceModel(A30, CFG))
    assert pred.predict(2000) > pred.predict(1000)
