"""Balancer (Algorithm 1) unit + property tests."""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # not installed in the CI image; see _hypothesis_compat
    from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.balancer import Balancer, CPIStats
from repro.core.predictor import profile_chunked, profile_prefill
from repro.serving.hardware import A100, A30, DeviceModel

CFG = get_config("llama3-8b")
LO = DeviceModel(A30, CFG)
HI = DeviceModel(A100, CFG)


def _balancer():
    return Balancer(profile_prefill(LO), profile_chunked(HI))


def _stats(n_decode=32, dctx=40_000, free=100_000):
    return CPIStats(n_decode=n_decode, decode_ctx_sum=dctx,
                    free_kv_blocks=free, block_size=16,
                    max_batched_tokens=512)


def test_fallback_when_cpi_full():
    """Alg 1 line 1: too few free KV blocks -> whole prompt on the PPI."""
    b = _balancer()
    assert b.partial_prefill_length(1600, _stats(free=10)) == 1600


def test_split_is_interior_and_balanced():
    b = _balancer()
    l_in = 4096
    lp = b.partial_prefill_length(l_in, _stats())
    assert 1 <= lp <= l_in
    # the chosen split's |T_prefill - T_chunked| is the minimum over a
    # dense grid (argmin property)
    stats = _stats()

    def gap(lp_c):
        t_p = b.prefill_pred.predict(lp_c)
        n_p = stats.max_batched_tokens - stats.n_decode
        l_c = l_in - lp_c
        n_iter = np.ceil(l_c / n_p)
        l_last = lp_c + np.floor(l_c / n_p) * n_p
        t_c = n_iter * b.chunked_pred.predict((l_in + l_last) / 2,
                                              stats.decode_ctx_sum)
        return abs(t_p - t_c)

    grid = np.ceil(np.arange(1, 513) / 512 * l_in)
    best = min(gap(g) for g in grid)
    assert gap(lp) <= best * 1.0001


@settings(max_examples=40, deadline=None)
@given(l_in=st.integers(2, 16384), n_decode=st.integers(0, 400),
       dctx=st.integers(0, 400_000))
def test_split_always_valid(l_in, n_decode, dctx):
    b = _balancer()
    lp = b.partial_prefill_length(l_in, _stats(n_decode=min(n_decode, 500),
                                               dctx=dctx))
    assert 1 <= lp <= l_in


def test_more_decode_load_shifts_split_to_ppi():
    """With a busier CPI (more decode context), chunked iterations are
    slower, so the balancer should give the PPI at least as much work."""
    b = _balancer()
    lp_idle = b.partial_prefill_length(8192, _stats(n_decode=0, dctx=0))
    lp_busy = b.partial_prefill_length(8192, _stats(n_decode=450,
                                                    dctx=600_000))
    assert lp_busy >= lp_idle
