"""THE Cronus invariant, property-tested: for any split point, partial
prefill + chunked continuation produces the same logits/KV as a monolithic
prefill — across every architecture family (KV caches, MLA latents, SSM
states, hybrid, cross-attention)."""
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # not installed in the CI image; see _hypothesis_compat
    from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.models import build_model

KEY = jax.random.PRNGKey(1)
ARCHS = ["llama3-8b", "mamba2-780m", "hymba-1.5b", "deepseek-v2-236b",
         "kimi-k2-1t-a32b", "gemma3-27b", "qwen3-32b", "whisper-base"]

_CACHE = {}


def _setup(arch):
    if arch not in _CACHE:
        cfg = get_config(arch, smoke=True)
        m = build_model(cfg, exact_moe=True)
        params = m.init_params(KEY)
        toks = jax.random.randint(jax.random.PRNGKey(7), (1, 24),
                                  0, cfg.vocab_size)
        enc = (jax.random.normal(KEY, (1, 16, cfg.d_model))
               if cfg.enc_dec else None)
        _CACHE[arch] = (cfg, m, params, toks, enc)
    return _CACHE[arch]


def _full_prefill(cfg, m, params, toks, enc):
    cache = m.init_cache(1, 64)
    kw = {}
    if cfg.enc_dec:
        kw["enc_out"] = m.encode(params, enc)
    lg, cache, _ = m.forward(params, toks, cache,
                             jnp.zeros((1,), jnp.int32), **kw)
    return lg


@pytest.mark.parametrize("arch", ARCHS)
def test_split_equals_full_fixed(arch):
    cfg, m, params, toks, enc = _setup(arch)
    want = _full_prefill(cfg, m, params, toks, enc)
    for sp in (1, 11, 23):
        cache = m.init_cache(1, 64)
        kw = {"enc_out": m.encode(params, enc)} if cfg.enc_dec else {}
        lg1, cache, _ = m.forward(params, toks[:, :sp], cache,
                                  jnp.zeros((1,), jnp.int32), **kw)
        lg2, cache, _ = m.forward(params, toks[:, sp:], cache,
                                  jnp.full((1,), sp, jnp.int32))
        got = jnp.concatenate([lg1, lg2], 1).astype(jnp.float32)
        err = jnp.max(jnp.abs(got - want.astype(jnp.float32)))
        assert float(err) < 2e-2, (arch, sp, float(err))


@settings(max_examples=12, deadline=None)
@given(sp1=st.integers(1, 22), arch=st.sampled_from(
    ["llama3-8b", "mamba2-780m", "hymba-1.5b", "kimi-k2-1t-a32b"]))
def test_split_equals_full_property(arch, sp1):
    """Random split points; also tests double splits (three chunks)."""
    cfg, m, params, toks, enc = _setup(arch)
    want = _full_prefill(cfg, m, params, toks, enc)
    sp2 = min(sp1 + 7, 23)
    cache = m.init_cache(1, 64)
    parts, cl = [], 0
    for lo, hi in ((0, sp1), (sp1, sp2), (sp2, 24)):
        if lo == hi:
            continue
        lg, cache, _ = m.forward(params, toks[:, lo:hi], cache,
                                 jnp.full((1,), lo, jnp.int32))
        parts.append(lg)
    got = jnp.concatenate(parts, 1).astype(jnp.float32)
    err = jnp.max(jnp.abs(got - want.astype(jnp.float32)))
    assert float(err) < 2e-2, (arch, sp1, sp2, float(err))


def test_ring_buffer_window_decode():
    """Sliding-window ring cache (s_kv < sequence) must equal a full cache
    masked to the same window — the long_500k decode contract."""
    cfg = get_config("llama3-8b", smoke=True)
    window = 16
    m = build_model(cfg, window_override=window)
    params = m.init_params(KEY)
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 40),
                              0, cfg.vocab_size)
    # oracle: full cache with window masking
    cache_f = m.init_cache(1, 64)
    lg_full, cache_f, _ = m.forward(params, toks, cache_f,
                                    jnp.zeros((1,), jnp.int32))
    # ring: window + chunk slots (the ring contract: writes must not evict
    # entries still inside the earliest in-chunk query's window)
    cache_r = m.init_cache(1, window + 8)
    lg_last = None
    for lo in range(0, 40, 8):
        lg, cache_r, _ = m.forward(params, toks[:, lo:lo + 8], cache_r,
                                   jnp.full((1,), lo, jnp.int32))
        lg_last = lg
    err = jnp.max(jnp.abs(lg_last[:, -1].astype(jnp.float32)
                          - lg_full[:, -1].astype(jnp.float32)))
    assert float(err) < 2e-2, float(err)
