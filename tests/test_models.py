"""Per-architecture smoke tests: reduced variant of each assigned family
runs one forward, one decode step, and one train step on CPU; output shapes
and finiteness asserted. (Full configs are exercised only via the dry-run.)
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.n_experts <= 4
    m = build_model(cfg, exact_moe=True)
    params = m.init_params(KEY)
    b, s = 2, 16
    cache = m.init_cache(b, 64)

    if cfg.enc_dec:
        enc_emb = jax.random.normal(KEY, (b, 32, cfg.d_model))
        enc_out = m.encode(params, enc_emb)
        toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
        logits, cache2, _ = m.forward(params, toks, cache,
                                      jnp.zeros((b,), jnp.int32),
                                      enc_out=enc_out)
    else:
        if cfg.embeddings_input:
            inp = jax.random.normal(KEY, (b, s, cfg.d_model))
        else:
            inp = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
        logits, cache2, _ = m.forward(params, inp, cache,
                                      jnp.zeros((b,), jnp.int32))
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    # one decode step
    tok = jnp.argmax(logits[:, -1:], -1)
    cl = jnp.full((b,), s, jnp.int32)
    if cfg.embeddings_input and not cfg.enc_dec:
        dec_in = params["embed"][tok]
    else:
        dec_in = tok
    logits2, _, _ = m.forward(params, dec_in, cache2, cl, decode=True)
    assert logits2.shape == (b, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2.astype(jnp.float32)).all())

    # one train step (loss is finite)
    if cfg.enc_dec:
        batch = {"enc_emb": jax.random.normal(KEY, (b, 32, cfg.d_model)),
                 "tokens": jax.random.randint(KEY, (b, s + 1), 0, cfg.vocab_size)}
    else:
        batch = {"tokens": jax.random.randint(KEY, (b, s + 1), 0, cfg.vocab_size)}
    loss = m.loss(params, batch)
    assert bool(jnp.isfinite(loss))


def test_param_counts_match_citations():
    # sanity: derived parameter counts are near the models' nameplates
    approx = {
        "kimi-k2-1t-a32b": (1.0e12, 0.1),
        "deepseek-v2-236b": (236e9, 0.05),
        "llama3-8b": (8e9, 0.05),
        "qwen2-7b": (7.6e9, 0.05),
        "mamba2-780m": (780e6, 0.05),
        "qwen2-vl-72b": (72e9, 0.05),
    }
    for arch, (target, tol) in approx.items():
        n = get_config(arch).param_count()
        assert abs(n - target) / target < max(tol, 0.1), (arch, n, target)


def test_moe_active_params():
    cfg = get_config("kimi-k2-1t-a32b")
    active = cfg.active_param_count()
    assert 25e9 < active < 40e9  # "a32b"
