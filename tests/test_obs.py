"""The flight recorder: tracing-off bit-identity, deterministic traces,
Chrome trace_event schema validity, flow pairing, the trace_report
overlap/TTFT analysis cross-checked against ``aggregate``, and the
autoscaler's unified event schema."""
import importlib.util
import json
import os

import pytest

from repro.configs import get_config
from repro.serving.api import ServeSpec
from repro.serving.simulator import APPROACHES
from repro.serving.trace import make_trace
from repro.workloads import OpenLoopDriver

CFG = get_config("llama3-8b")

_TR_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "tools", "trace_report.py")
_spec = importlib.util.spec_from_file_location("trace_report", _TR_PATH)
trace_report = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(trace_report)


def _traced_run(spec, reqs, open_loop=False):
    service = spec.build()
    tracer = service.start_trace()
    if open_loop:
        OpenLoopDriver(service).run(reqs)
        metrics = service.metrics(queueing=True)
    else:
        metrics = service.run(reqs)
    return service, tracer, metrics


# ---------------------------------------------------------------------------
# contract 1: tracing off is free — aggregates byte-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("interval", [0.0, 1 / 7.0],
                         ids=["maxtput", "staggered"])
@pytest.mark.parametrize("approach", APPROACHES)
def test_tracing_leaves_aggregates_bit_identical(approach, interval):
    reqs = make_trace(50, seed=0, interval=interval)
    plain = ServeSpec(approach=approach).build().run(reqs.fresh())
    _, _, traced = _traced_run(ServeSpec(approach=approach), reqs.fresh())
    assert json.dumps(traced, sort_keys=True) == \
        json.dumps(plain, sort_keys=True)


def test_tracer_off_by_default_everywhere():
    service = ServeSpec(approach="cronus").build()
    assert service.tracer is None
    for ep in service.endpoints:
        for eng in ep.engines:
            assert eng.tracer is None
            assert eng.allocator.trace_engine is None
    with pytest.raises(ValueError, match="start_trace"):
        service.export_trace("/tmp/never.json")


# ---------------------------------------------------------------------------
# contract 2: tracing on is deterministic
# ---------------------------------------------------------------------------

def test_trace_deterministic_across_runs():
    reqs = make_trace(40, seed=3, interval=1 / 9.0)
    runs = []
    for _ in range(2):
        _, tracer, _ = _traced_run(ServeSpec(approach="cronus"),
                                   reqs.fresh())
        runs.append(tracer.to_chrome())
    assert json.dumps(runs[0], sort_keys=True) == \
        json.dumps(runs[1], sort_keys=True)


def test_start_trace_idempotent():
    service = ServeSpec(approach="cronus").build()
    assert service.start_trace() is service.start_trace()


# ---------------------------------------------------------------------------
# schema: valid trace_event JSON, nested spans, monotone tracks, flows
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("approach", APPROACHES)
def test_trace_structurally_valid(approach):
    reqs = make_trace(30, seed=1, interval=1 / 8.0)
    _, tracer, _ = _traced_run(ServeSpec(approach=approach), reqs.fresh())
    events = tracer.to_chrome()
    json.dumps(events)                       # every event serializable
    assert trace_report.validate(events) == []
    # every lane got named metadata
    names = trace_report.track_names(events)
    used = {(e["pid"], e["tid"]) for e in events if e.get("ph") != "M"}
    assert used <= set(names)


def test_export_file_shape(tmp_path):
    reqs = make_trace(10, seed=0, interval=0.0)
    service, tracer, _ = _traced_run(ServeSpec(approach="cronus"),
                                     reqs.fresh())
    path = tmp_path / "run.json"
    service.export_trace(str(path))
    data = json.loads(path.read_text())
    assert set(data) == {"traceEvents", "displayTimeUnit"}
    assert data["traceEvents"] == tracer.to_chrome()
    # metadata first, then strictly ts-sorted events
    body = [e for e in data["traceEvents"] if e["ph"] != "M"]
    assert all(b["ts"] <= a["ts"] for b, a in zip(body, body[1:]))


def test_flow_pairs_exactly_once_per_delivered_handoff():
    reqs = make_trace(40, seed=2, interval=1 / 8.0)
    service, tracer, _ = _traced_run(ServeSpec(approach="cronus"),
                                     reqs.fresh())
    sends = [e for e in tracer.events if e["ph"] == "s"]
    recvs = [e for e in tracer.events if e["ph"] == "f"]
    eng = service.runtime.transfers
    assert eng.n_transfers > 0
    assert len(sends) == len(recvs) == eng.n_transfers - eng.n_cancelled
    assert sorted(e["id"] for e in sends) == sorted(e["id"] for e in recvs)
    # tokens on the wire match the engine's own per-kind ledger
    by_kind = {}
    for e in recvs:
        by_kind[e["args"]["kind"]] = (by_kind.get(e["args"]["kind"], 0)
                                      + e["args"]["tokens"])
    assert by_kind == dict(eng.tokens_by_kind)


# ---------------------------------------------------------------------------
# trace_report: the analysis proves the paper's claim from the trace alone
# ---------------------------------------------------------------------------

def test_overlap_cronus_positive_disagg_zero():
    reqs = make_trace(40, seed=0, arrival="poisson:6",
                      vocab_size=CFG.vocab_size)
    _, tr_c, _ = _traced_run(ServeSpec(approach="cronus",
                                       arrival="poisson:6"),
                             reqs.fresh(), open_loop=True)
    _, tr_d, _ = _traced_run(ServeSpec(approach="disagg_hl",
                                       arrival="poisson:6"),
                             reqs.fresh(), open_loop=True)
    cronus = trace_report.overlap_report(tr_c.to_chrome())
    disagg = trace_report.overlap_report(tr_d.to_chrome())
    # Cronus's high-end GPU decodes while chewing the migrated prefill
    # remainder; pure disaggregation's decoder never sees migrated
    # prefill chunks at all — the paper's core claim, mechanically
    assert cronus["overlap_frac"] > 0.0
    assert cronus["migrated_busy_s"] > 0.0
    assert disagg["overlap_frac"] == 0.0
    assert disagg["per_track"] == {}


def test_ttft_decomposition_matches_aggregate():
    reqs = make_trace(40, seed=0, arrival="poisson:6",
                      vocab_size=CFG.vocab_size)
    _, tracer, metrics = _traced_run(ServeSpec(approach="cronus",
                                               arrival="poisson:6"),
                                     reqs.fresh(), open_loop=True)
    ttft = trace_report.ttft_decomposition(tracer.to_chrome())
    assert ttft["n_finished"] == metrics["completed"]
    for key in ("queueing_p50", "queueing_p99", "ttft_service_p99"):
        assert ttft[key] == pytest.approx(metrics[key], abs=1e-6), key


def test_bubble_report_covers_every_engine_lane():
    reqs = make_trace(30, seed=1, interval=1 / 8.0)
    _, tracer, _ = _traced_run(ServeSpec(approach="cronus"), reqs.fresh())
    bubbles = trace_report.bubble_report(tracer.to_chrome())
    assert set(bubbles) == {"cronus/ppi", "cronus/cpi"}
    for lane in bubbles.values():
        assert 0.0 <= lane["bubble_frac"] < 1.0
        assert lane["n_iterations"] > 0
        assert lane["busy_s"] <= lane["span_s"] + 1e-9


def test_validate_flags_broken_traces():
    ok = [{"ph": "X", "pid": 1, "tid": 1, "ts": 0.0, "dur": 5.0,
           "name": "iter"}]
    assert trace_report.validate(ok) == []
    regressed = ok + [{"ph": "i", "pid": 1, "tid": 1, "ts": -4.0,
                       "name": "late", "s": "t"}]
    assert any("regressed" in p for p in trace_report.validate(regressed))
    straddle = ok + [{"ph": "X", "pid": 1, "tid": 1, "ts": 3.0, "dur": 9.0,
                      "name": "iter"}]
    assert any("straddle" in p for p in trace_report.validate(straddle))
    lone = [{"ph": "s", "pid": 1, "tid": 1, "ts": 1.0, "id": 7,
             "name": "kv_send", "cat": "flow"}]
    assert any("flow id 7" in p for p in trace_report.validate(lone))


# ---------------------------------------------------------------------------
# request lifecycle on the trace: submit -> ... -> finish/cancel
# ---------------------------------------------------------------------------

def test_request_lifecycle_events_present():
    reqs = make_trace(20, seed=4, interval=1 / 6.0)
    service, tracer, metrics = _traced_run(ServeSpec(approach="cronus"),
                                           reqs.fresh())
    by_name = {}
    for e in tracer.events:
        by_name.setdefault(e["name"], []).append(e)
    n = metrics["completed"]
    assert len(by_name["submit"]) == n
    assert len(by_name["finish"]) == n
    assert len(by_name["route"]) == n
    assert len(by_name["balancer_split"]) == n
    assert len(by_name["service_start"]) >= n
    # one async request lifeline per submission, balanced
    assert len([e for e in tracer.events if e["ph"] == "b"]) == n
    assert len([e for e in tracer.events if e["ph"] == "e"]) == n


def test_cancel_shows_on_trace():
    reqs = make_trace(10, seed=0, interval=0.0)
    service = ServeSpec(approach="cronus").build()
    service.start_trace()
    handles = [service.submit(r) for r in reqs]
    handles[3].cancel()
    service.drain()
    cancels = [e for e in service.tracer.events if e["name"] == "cancel"]
    assert len(cancels) == 1 and cancels[0]["args"]["req"] == "r3"
    ends = [e for e in service.tracer.events if e["ph"] == "e"]
    assert sum(1 for e in ends if e.get("args", {}).get("cancelled")) == 1


# ---------------------------------------------------------------------------
# satellite: autoscaler events ride the same tracer schema
# ---------------------------------------------------------------------------

def test_autoscaler_events_on_control_track():
    spec = ServeSpec(approach="cronus", arrival="ramp:1:8:120",
                     autoscale="slo:goodput>=0.9:cooldown=10",
                     inventory="A100:1,A10:4")
    reqs = make_trace(300, seed=0, arrival=spec.arrival,
                      vocab_size=CFG.vocab_size)
    service = spec.build()
    service.start_trace()
    OpenLoopDriver(service).run(reqs)
    scaler = service.autoscaler
    assert scaler.events                      # compat view still filled
    traced = [e for e in service.tracer.events
              if e.get("cat") == "autoscale"]
    assert len(traced) == len(scaler.events)
    for inst, ev in zip(traced, scaler.events):
        assert inst["ph"] == "i"
        assert inst["name"] == ev["action"]
        assert inst["ts"] == pytest.approx(ev["t"] * 1e6)
        assert inst["args"] == {k: v for k, v in ev.items()
                                if k not in ("t", "action")}
    # scale-ups wire the new endpoint into the tracer: its lane shows up
    names = trace_report.track_names(service.tracer.to_chrome())
    assert any(n.startswith("as") for n in names.values())


def test_autoscaler_events_without_tracer_unchanged():
    spec = ServeSpec(approach="cronus", arrival="ramp:1:8:120",
                     autoscale="slo:goodput>=0.9:cooldown=10",
                     inventory="A100:1,A10:4")
    reqs = make_trace(300, seed=0, arrival=spec.arrival,
                      vocab_size=CFG.vocab_size)
    service = spec.build()
    OpenLoopDriver(service).run(reqs)
    rep = service.autoscaler.report(service.now)
    assert rep["n_scale_ups"] >= 1 and rep["events"]


# ---------------------------------------------------------------------------
# satellite: transfer stats surface through opt-in utilization
# ---------------------------------------------------------------------------

def test_transfer_stats_in_utilization_opt_in():
    reqs = make_trace(30, seed=1, interval=1 / 8.0)
    service = ServeSpec(approach="cronus").build()
    base = service.run(reqs.fresh())
    assert "utilization" not in base          # default dict untouched
    util = service.metrics(utilization=True)["utilization"]
    t = util["transfers"]
    assert t["n_transfers"] > 0
    assert any(k.startswith("tokens_") for k in t)
    assert t["n_cancelled"] >= 0
    # transfer-free topology: utilization keys stay exactly per-endpoint
    lone = ServeSpec(cluster="2xworker:A10").build()
    lone.run(make_trace(10, seed=0, interval=0.0).fresh())
    assert "transfers" not in lone.metrics(utilization=True)["utilization"]
