"""MoE: capacity dispatch (sort/gather) vs dense all-experts oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.moe import init_moe, moe_block, moe_block_dense_ref

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def moe_setup():
    cfg = get_config("kimi-k2-1t-a32b", smoke=True)
    p = init_moe(KEY, cfg)
    return cfg, p


def test_exact_dispatch_matches_dense(moe_setup):
    cfg, p = moe_setup
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    want = moe_block_dense_ref(p, cfg, x)
    got, aux = moe_block(p, cfg, x, exact=True)   # capacity C = T: no drops
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
    assert float(aux) > 0  # load-balance loss is live


def test_capacity_dispatch_close_to_dense(moe_setup):
    """With cf-bounded capacity a few tokens may drop — outputs must agree
    on the vast majority of positions."""
    cfg, p = moe_setup
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 32, cfg.d_model),
                          jnp.float32)
    want = moe_block_dense_ref(p, cfg, x)
    got, _ = moe_block(p, cfg, x, exact=False)
    close = np.isclose(np.asarray(got), np.asarray(want),
                       atol=1e-4, rtol=1e-4).all(axis=-1)
    assert close.mean() > 0.85, close.mean()


def test_moe_permutation_equivariance(moe_setup):
    """Token order must not affect per-token outputs (exact mode)."""
    cfg, p = moe_setup
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 12, cfg.d_model))
    perm = jax.random.permutation(jax.random.PRNGKey(4), 12)
    y, _ = moe_block(p, cfg, x, exact=True)
    y_p, _ = moe_block(p, cfg, x[:, perm], exact=True)
    np.testing.assert_allclose(np.asarray(y[:, perm]), np.asarray(y_p),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.slow
def test_shardmap_dispatch_subprocess():
    """shard_map expert-parallel dispatch (the HC1-2 optimization) matches
    the dense oracle on a real 2x2 mesh — run in a subprocess because the
    test session's jax is pinned to 1 device."""
    import os
    import subprocess
    import sys
    script = os.path.join(os.path.dirname(__file__), "helpers",
                          "check_shardmap_moe.py")
    proc = subprocess.run([sys.executable, script], capture_output=True,
                          text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
