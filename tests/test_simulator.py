"""Paper-claims trend tests on the discrete-event path (NullExecutor).

Thresholds are deliberately loose — they assert the ORDERING the paper
establishes (Table 2, Table 3, Fig. 4), not its exact numbers.
"""
import pytest

from repro.configs import get_config
from repro.serving.hardware import A10, A100
from repro.serving.simulator import compare_all, utilization_table
from repro.serving.trace import make_trace

CFG = get_config("llama3-8b")


@pytest.fixture(scope="module")
def tput_results():
    reqs = make_trace(400, seed=0, interval=0.0)   # max-throughput mode
    return compare_all(CFG, A100, A10, reqs)


def test_throughput_ordering(tput_results):
    r = tput_results
    t = {k: v["throughput"] for k, v in r.items()}
    # Table 2: Cronus ~ DP, both well above PP and both disagg variants
    assert t["cronus"] > 0.85 * t["dp"]
    assert t["cronus"] > 1.3 * t["pp"]
    assert t["cronus"] > 1.5 * t["disagg_hl"]
    assert t["cronus"] > 1.3 * t["disagg_lh"]


def test_tbt_ordering(tput_results):
    r = tput_results
    # Fig 4 row 2: disagg L-H best TBT (dedicated decode GPU);
    # Cronus <= DP and PP (all decode on the high-end device)
    assert r["disagg_lh"]["tbt_p99"] < r["cronus"]["tbt_p99"]
    assert r["cronus"]["tbt_p99"] < r["pp"]["tbt_p99"]
    assert r["cronus"]["tbt_p99"] <= r["dp"]["tbt_p99"] * 1.05


def test_ttft_near_saturation():
    # 600 requests @ 7 req/s: the regime where DP's low-end queueing tips
    # (validated: cronus 1.36 s vs dp 2.03 s vs pp saturated). Shorter
    # traces don't reach DP's tipping point and the margin inverts.
    reqs = make_trace(600, seed=1, interval=1 / 7.0)
    r = compare_all(CFG, A100, A10, reqs,
                    approaches=("cronus", "dp", "pp"))
    # Fig 4 row 1: Cronus TTFT P99 below DP and far below PP near
    # saturation (paper reports up to 55% below DP)
    assert r["cronus"]["ttft_p99"] < r["dp"]["ttft_p99"]
    assert r["cronus"]["ttft_p99"] < r["pp"]["ttft_p99"]


def test_disagg_load_imbalance():
    """Table 3: the dedicated instance on the low-end side saturates
    (~100%) while the high-end side idles (<= ~60%)."""
    reqs = make_trace(250, seed=0, interval=0.0)
    table = utilization_table(CFG, A100, A10, reqs)
    # H-L: prefill on high-end (underutilized), decode on low-end (bound)
    assert table["disagg_hl"]["decode_util"] > 0.6
    assert table["disagg_hl"]["prefill_util"] < 0.6
    # L-H: prefill on low-end (bound), decode on high-end (underutilized)
    assert table["disagg_lh"]["prefill_util"] > 0.6
    assert table["disagg_lh"]["decode_util"] < 0.6


def test_all_requests_complete(tput_results):
    for name, m in tput_results.items():
        assert m["completed"] == 400, name
