"""Sharding rules: name-based param specs, divisibility fallback, and a
(subprocess) production-mesh dry-run smoke covering one arch per family.

The in-process tests use a 1-device mesh (this container); the full 512-
device sweep is results/dryrun (EXPERIMENTS.md §Dry-run).
"""
import os
import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import build_model
from repro.models.sharding import divisible_spec, param_pspec


class FakeMesh:
    shape = {"data": 16, "model": 16}


RULES = {"model": "model", "batch": ("data",), "vocab": "model"}


def _pspec_for(tree_path_leaf):
    pass


def test_divisible_spec_drops_uneven():
    spec = divisible_spec(P("model", None), (50_280, 1536), FakeMesh())
    assert spec == P(None, None)
    spec2 = divisible_spec(P("model", None), (51_200, 1536), FakeMesh())
    assert spec2 == P("model", None)


def test_param_specs_by_name():
    cfg = get_config("kimi-k2-1t-a32b", smoke=True)
    m = build_model(cfg)
    params = jax.eval_shape(lambda: m.init_params(jax.random.PRNGKey(0)))
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    specs = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", p)) for p in path)
        specs[key] = (param_pspec(path, leaf, RULES), leaf.shape)
    # routed expert weights: expert dim sharded (expert parallelism)
    moe_gate = [v for k, v in specs.items()
                if "moe" in k and k.endswith("w_gate") and "shared" not in k]
    assert moe_gate and all(s[0] == P(None, "model", None, None)
                            for s in moe_gate), moe_gate
    # shared expert / dense mlp: ffn dim sharded
    shared = [v for k, v in specs.items()
              if "shared_0" in k and k.endswith("w_gate")]
    assert shared and all(s[0] == P(None, None, "model") for s in shared)
    # attention projections
    wq = [v for k, v in specs.items() if k.endswith("attn/wq")]
    assert wq and all(s[0] == P(None, None, "model") for s in wq)


@pytest.mark.skipif(not os.environ.get("RUN_DRYRUN_TESTS"),
                    reason="slow 512-device subprocess dry-run; "
                           "set RUN_DRYRUN_TESTS=1 (covered by results/dryrun)")
@pytest.mark.parametrize("arch,shape", [
    ("llama3-8b", "decode_32k"),
    ("mamba2-780m", "long_500k"),
    ("kimi-k2-1t-a32b", "prefill_32k"),
])
def test_dryrun_subprocess(arch, shape):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape],
        capture_output=True, text=True, timeout=1800,
        env={**os.environ, "PYTHONPATH": "src"})
    assert proc.returncode == 0, proc.stderr[-2000:]
