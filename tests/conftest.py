import os
import sys

# Deterministic XLA CPU codegen: by default XLA splits modules across
# parallel codegen tasks nondeterministically, which perturbs fp fusion
# results run-to-run and flips greedy near-ties in the token-equality
# oracles (diagnosed via schedule-identical traces with differing tokens).
# Must be set before the first jax import.
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_cpu_parallel_codegen_split_count=1")

# tests see ONE device (the dry-run subprocesses set their own XLA_FLAGS)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")
