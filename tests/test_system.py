"""End-to-end behaviour: Cronus / disaggregated / DP with REAL JAX
execution produce token streams identical to a monolithic single-request
oracle; the Balancer picks non-trivial splits; metrics are recorded."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.balancer import Balancer
from repro.core.baselines import build_dp
from repro.core.cronus import build_cronus, build_disaggregated
from repro.core.executor import RealExecutor
from repro.core.predictor import profile_chunked, profile_prefill
from repro.core.request import Request
from repro.models import build_model
from repro.serving.hardware import A100, A30, DeviceModel

S_KV, SLOTS, CHUNK = 128, 4, 16
LENS = [(17, 5), (33, 8), (9, 4), (41, 6), (25, 3)]


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama3-8b", smoke=True)
    model = build_model(cfg, exact_moe=True)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(42)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n, _ in LENS]

    def oracle(prompt, out_len):
        # identical tensor shapes to the engines (same slot count, same
        # fixed chunk width) => bit-identical XLA reductions => the token
        # equality below is exact, not a fp coincidence
        ex = RealExecutor(model, params, max_slots=SLOTS, s_kv=S_KV,
                          chunk_pad=CHUNK)
        first, L = None, len(prompt)
        for lo in range(0, L, CHUNK):
            hi_ = min(lo + CHUNK, L)
            first = ex.prefill_chunk(0, prompt[lo:hi_], lo, hi_ == L)
        toks = [first]
        for t in range(out_len - 1):
            toks.append(ex.decode({0: toks[-1]}, {0: L + t})[0])
        return toks

    want = {f"r{i}": oracle(prompts[i], LENS[i][1]) for i in range(len(LENS))}
    hi, lo = DeviceModel(A100, cfg), DeviceModel(A30, cfg)
    return cfg, model, params, prompts, want, hi, lo


def _reqs(prompts):
    return [Request(req_id=f"r{i}", prompt=prompts[i].copy(),
                    output_len=LENS[i][1], arrival=0.0)
            for i in range(len(LENS))]


def _factory(model, params):
    def f(role):
        return RealExecutor(model, params, max_slots=SLOTS, s_kv=S_KV,
                            chunk_pad=CHUNK)
    return f


def test_cronus_matches_oracle(setup):
    """Structural run: everything completes, balancer splits non-trivially,
    metrics recorded. (Exact token equality vs the oracle is asserted by
    test_token_equivalence_subprocess in a fresh process — see
    helpers/check_token_equivalence.py for why.)"""
    cfg, model, params, prompts, want, hi, lo = setup
    bal = Balancer(profile_prefill(lo), profile_chunked(hi))
    sys_c = build_cronus(cfg, lo, hi, executor_factory=_factory(model, params),
                         balancer=bal, max_batched_tokens=16,
                         max_slots=SLOTS, block_size=4)
    res = sys_c.run(_reqs(prompts))
    assert res["completed"] == len(LENS)
    for r in sys_c.cpi.finished:
        assert len(r.generated) == r.output_len
        assert 1 <= r.partial_len <= r.input_len
        assert r.metrics.first_token_time is not None
        assert len(r.metrics.tbts) == r.output_len - 1
    assert res["throughput"] > 0 and res["ttft_p99"] > 0


def test_disagg_lh_matches_oracle(setup):
    cfg, model, params, prompts, want, hi, lo = setup
    sys_d = build_disaggregated(cfg, lo, hi,
                                executor_factory=_factory(model, params),
                                max_batched_tokens=16, max_slots=SLOTS,
                                block_size=4)
    res = sys_d.run(_reqs(prompts))
    assert res["completed"] == len(LENS)
    for r in sys_d.cpi.finished:
        assert len(r.generated) == r.output_len
        assert r.partial_len == r.input_len  # full prefill on the PPI


def test_dp_matches_oracle(setup):
    cfg, model, params, prompts, want, hi, lo = setup

    def f(role):
        return RealExecutor(model, params, max_slots=SLOTS, s_kv=S_KV,
                            chunk_pad=CHUNK)

    sys_dp = build_dp(cfg, hi, lo, executor_factory=f, max_slots=SLOTS,
                      block_size=4)
    res = sys_dp.run(_reqs(prompts))
    assert res["completed"] == len(LENS)
    fin = {r.req_id: r for e in sys_dp.engines for r in e.finished}
    assert len(fin) == len(LENS)
    for rid, r in fin.items():
        assert len(r.generated) == r.output_len


@pytest.mark.slow
def test_token_equivalence_subprocess():
    """THE correctness crown jewel: Cronus / Disagg / DP token streams ==
    monolithic oracle, bit-for-bit, in a clean process (see helper)."""
    import subprocess
    import sys as _sys
    script = __file__.replace("test_system.py",
                              "helpers/check_token_equivalence.py")
    proc = subprocess.run([_sys.executable, script], capture_output=True,
                          text=True, timeout=1200)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cronus_staggered_arrivals(setup):
    """Arrival times respected: TTFT measured from each arrival; every
    request completes with the right output length. (Exact token equality
    under arbitrary balancer splits is asserted by the canonical test above;
    here chunk boundaries shift with arrival-dependent CPI stats, which is
    compile-cache-sensitive on CPU — see conftest.)"""
    cfg, model, params, prompts, want, hi, lo = setup
    bal = Balancer(profile_prefill(lo), profile_chunked(hi))
    sys_c = build_cronus(cfg, lo, hi, executor_factory=_factory(model, params),
                         balancer=bal, max_batched_tokens=16,
                         max_slots=SLOTS, block_size=4)
    reqs = _reqs(prompts)
    for i, r in enumerate(reqs):
        r.arrival = i * 0.5
        r.metrics.arrival = r.arrival
    res = sys_c.run(reqs)
    assert res["completed"] == len(LENS)
    for r in sys_c.cpi.finished:
        assert len(r.generated) == r.output_len
        assert r.metrics.first_token_time >= r.metrics.arrival
        assert r.metrics.finish_time >= r.metrics.first_token_time
        # monotone non-decreasing token timestamps
        ts = [r.metrics.first_token_time] + r.metrics.token_times
        assert all(b >= a for a, b in zip(ts, ts[1:]))


def test_cronus_moe_and_ssm_archs(setup):
    """Cronus end-to-end with an MoE arch and an attention-free SSM arch —
    the families where the KV 'transfer' differs most (expert layers;
    constant-size recurrent state). Structural checks in-process; exact
    token equivalence is asserted by the subprocess helper (MoE dispatch is
    batch-composition-sensitive, and long-lived pytest processes perturb
    XLA CPU numerics — see helpers/check_token_equivalence.py)."""
    del setup
    for arch in ("kimi-k2-1t-a32b", "mamba2-780m"):
        n_reqs = 1 if arch.startswith("kimi") else 2
        cfg = get_config(arch, smoke=True)
        model = build_model(cfg, exact_moe=True)
        params = model.init_params(jax.random.PRNGKey(0))
        rng = np.random.default_rng(1)
        prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
                   for n in (19, 27)][:n_reqs]
        hi, lo = DeviceModel(A100, cfg), DeviceModel(A30, cfg)
        bal = Balancer(profile_prefill(lo), profile_chunked(hi))
        sys_c = build_cronus(cfg, lo, hi,
                             executor_factory=_factory(model, params),
                             balancer=bal, max_batched_tokens=16,
                             max_slots=SLOTS, block_size=4)
        reqs = [Request(req_id=f"r{i}", prompt=prompts[i].copy(),
                        output_len=4) for i in range(n_reqs)]
        res = sys_c.run(reqs)
        assert res["completed"] == n_reqs, arch
        for r in sys_c.cpi.finished:
            assert len(r.generated) == 4
            assert 1 <= r.partial_len <= r.input_len


def test_decode_offload_functional(setup):
    """Paper §6 future-work feature: bounded decode offload to the PPI —
    offloaded requests complete (on the PPI) with correct output lengths,
    and nothing is lost or duplicated."""
    cfg, model, params, prompts, want, hi, lo = setup
    bal = Balancer(profile_prefill(lo), profile_chunked(hi))
    sys_c = build_cronus(cfg, lo, hi, executor_factory=_factory(model, params),
                         balancer=bal, max_batched_tokens=16,
                         max_slots=SLOTS, block_size=4, decode_offload=True)
    # tiny CPI block pool -> Alg. 1 fallback fires -> offload path exercised
    sys_c.cpi.allocator = type(sys_c.cpi.allocator)(num_blocks=14,
                                                    block_size=4)
    sys_c.cpi.ecfg.num_kv_blocks = 14
    res = sys_c.run(_reqs(prompts))
    assert res["completed"] == len(LENS)
    done = {r.req_id for r in sys_c.cpi.finished} | {
        r.req_id for r in sys_c.ppi.finished}
    assert len(done) == len(LENS)
    for r in list(sys_c.cpi.finished) + list(sys_c.ppi.finished):
        assert len(r.generated) == r.output_len
