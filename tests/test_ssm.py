"""SSD (mamba2) numerics: chunked scan vs token-recurrent oracle; state
carry across chunk boundaries (the Cronus partial-prefill contract for
attention-free architectures)."""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # not installed in the CI image; see _hypothesis_compat
    from _hypothesis_compat import given, settings, st

from repro.models.ssm import ssd_chunked, ssd_ref

KEY = jax.random.PRNGKey(0)


def _inputs(b, s, h, p, n, key=KEY):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.1
    a_neg = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    b_in = jax.random.normal(ks[3], (b, s, n))
    c_in = jax.random.normal(ks[4], (b, s, n))
    h0 = jax.random.normal(jax.random.fold_in(key, 9), (b, h, p, n)) * 0.1
    return x, dt, a_neg, b_in, c_in, h0


@settings(max_examples=10, deadline=None)
@given(s=st.integers(1, 40), chunk=st.sampled_from([4, 8, 16]))
def test_ssd_chunked_matches_recurrent(s, chunk):
    x, dt, a_neg, b_in, c_in, h0 = _inputs(2, s, 3, 4, 5)
    y_ref, h_ref = ssd_ref(x, dt, a_neg, b_in, c_in, h0)
    y, h = ssd_chunked(x, dt, a_neg, b_in, c_in, h0, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               atol=1e-4, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(split=st.integers(1, 31))
def test_ssd_state_carry(split):
    """scan(x) == scan(x[:split]) then scan(x[split:], h_mid) — exactly the
    PPI -> CPI state handoff."""
    x, dt, a_neg, b_in, c_in, h0 = _inputs(1, 32, 2, 4, 3)
    y_full, h_full = ssd_chunked(x, dt, a_neg, b_in, c_in, h0, 8)
    y1, h_mid = ssd_chunked(x[:, :split], dt[:, :split], a_neg,
                            b_in[:, :split], c_in[:, :split], h0, 8)
    y2, h_end = ssd_chunked(x[:, split:], dt[:, split:], a_neg,
                            b_in[:, split:], c_in[:, split:], h_mid, 8)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_end), np.asarray(h_full),
                               atol=1e-4, rtol=1e-4)


def test_ssd_padding_neutral():
    """Lengths not divisible by the chunk: padding must not change h."""
    x, dt, a_neg, b_in, c_in, h0 = _inputs(1, 13, 2, 4, 3)
    _, h_a = ssd_chunked(x, dt, a_neg, b_in, c_in, h0, 8)
    _, h_b = ssd_ref(x, dt, a_neg, b_in, c_in, h0)
    np.testing.assert_allclose(np.asarray(h_a), np.asarray(h_b),
                               atol=1e-4, rtol=1e-4)
