"""Property tests: paged-KV block allocator invariants under random
alloc/extend/free sequences (no double allocation, no leaks, N_free exact)."""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # not installed in the CI image; see _hypothesis_compat
    from _hypothesis_compat import given, settings, st

from repro.kvcache import BlockAllocator


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "extend", "free"]),
                          st.integers(0, 9), st.integers(1, 400)),
                min_size=1, max_size=60))
def test_allocator_invariants(ops):
    a = BlockAllocator(num_blocks=128, block_size=16)
    live = {}
    for op, rid_i, tokens in ops:
        rid = f"r{rid_i}"
        if op == "alloc" and rid not in live:
            if a.can_allocate(tokens):
                blocks = a.allocate(rid, tokens)
                assert len(blocks) == a.blocks_needed(tokens)
                live[rid] = tokens
        elif op == "extend" and rid in live:
            new_total = live[rid] + tokens
            need = a.blocks_needed(new_total) - a.blocks_needed(live[rid])
            if need <= a.num_free:
                a.extend(rid, live[rid], new_total)
                live[rid] = new_total
        elif op == "free" and rid in live:
            a.free(rid)
            del live[rid]
        a.check_invariants()
    used = sum(a.blocks_needed(t) for t in live.values())
    assert a.num_free == a.num_blocks - used


def test_allocator_oom():
    a = BlockAllocator(num_blocks=4, block_size=16)
    a.allocate("r1", 64)
    assert a.num_free == 0
    with pytest.raises(MemoryError):
        a.allocate("r2", 1)
    a.free("r1")
    assert a.num_free == 4
