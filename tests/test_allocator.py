"""Property tests: paged-KV block allocator invariants under random
alloc/extend/free/preempt sequences (no double allocation, no leaks,
N_free exact) — runs with real hypothesis or the deterministic
``_hypothesis_compat`` shim when it is not installed."""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # not installed in the CI image; see _hypothesis_compat
    from _hypothesis_compat import given, settings, st

from repro.kvcache import BlockAllocator


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "extend", "free"]),
                          st.integers(0, 9), st.integers(1, 400)),
                min_size=1, max_size=60))
def test_allocator_invariants(ops):
    a = BlockAllocator(num_blocks=128, block_size=16)
    live = {}
    for op, rid_i, tokens in ops:
        rid = f"r{rid_i}"
        if op == "alloc" and rid not in live:
            if a.can_allocate(tokens):
                blocks = a.allocate(rid, tokens)
                assert len(blocks) == a.blocks_needed(tokens)
                live[rid] = tokens
        elif op == "extend" and rid in live:
            new_total = live[rid] + tokens
            if a.can_extend_to(rid, new_total):
                a.extend_to(rid, new_total)
                live[rid] = new_total
        elif op == "free" and rid in live:
            a.free(rid)
            del live[rid]
        a.check_invariants()
    used = sum(a.blocks_needed(t) for t in live.values())
    assert a.num_free == a.num_blocks - used


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "extend_to", "preempt",
                                           "free"]),
                          st.integers(0, 9), st.integers(1, 400)),
                min_size=1, max_size=80))
def test_allocator_preempt_roundtrips(ops):
    """The scheduler's dynamic-growth lifecycle: lazy allocate ->
    ``extend_to`` as context grows -> preempt (free all, re-admit later,
    grow again). Invariants hold at every step and preemption returns
    exactly the blocks the request held."""
    a = BlockAllocator(num_blocks=128, block_size=16)
    live = {}                       # req_id -> covered tokens
    for op, rid_i, tokens in ops:
        rid = f"r{rid_i}"
        if op == "alloc" and rid not in live:
            if a.can_allocate(tokens):
                a.allocate(rid, tokens)
                live[rid] = tokens
        elif op == "extend_to" and rid in live:
            target = max(live[rid], tokens)
            if a.can_extend_to(rid, target):
                a.extend_to(rid, target)
                assert a.owned_blocks(rid) == a.blocks_needed(target)
                live[rid] = target
            else:
                # preemption-by-recompute: release everything; a later
                # alloc readmits from scratch
                held = a.owned_blocks(rid)
                free_before = a.num_free
                a.free(rid)
                del live[rid]
                assert a.num_free == free_before + held
        elif op == "preempt" and rid in live:
            held = a.owned_blocks(rid)
            free_before = a.num_free
            a.free(rid)
            assert a.num_free == free_before + held
            # immediate re-admission at prompt size must fit again
            readmit = min(tokens, 64)
            if a.can_allocate(readmit):
                a.allocate(rid, readmit)
                live[rid] = readmit
            else:
                del live[rid]
        elif op == "free" and rid in live:
            a.free(rid)
            del live[rid]
        a.check_invariants()
    used = sum(a.blocks_needed(t) for t in live.values())
    assert a.num_free == a.num_blocks - used


def test_extend_to_is_idempotent():
    a = BlockAllocator(num_blocks=8, block_size=16)
    a.allocate("r", 20)             # 2 blocks
    assert a.extend_to("r", 20) == []
    assert a.extend_to("r", 16) == []      # shrink requests are no-ops
    assert len(a.extend_to("r", 40)) == 1  # 3 blocks total
    assert a.owned_blocks("r") == 3
    a.check_invariants()


def test_extend_to_oom():
    a = BlockAllocator(num_blocks=6, block_size=16)
    a.allocate("r1", 48)
    a.allocate("r2", 32)
    assert not a.can_extend_to("r1", 80)
    with pytest.raises(MemoryError):
        a.extend_to("r1", 80)
    a.free("r2")                    # the preemption path
    assert a.can_extend_to("r1", 80)
    a.extend_to("r1", 80)
    a.check_invariants()


def test_allocator_oom():
    a = BlockAllocator(num_blocks=4, block_size=16)
    a.allocate("r1", 64)
    assert a.num_free == 0
    with pytest.raises(MemoryError):
        a.allocate("r2", 1)
    a.free("r1")
    assert a.num_free == 4
