"""Cluster runtime + router + topology tests (NullExecutor, roofline time).

Covers: router policy unit behaviour (least-loaded picks most free KV
blocks; session affinity is sticky; weighted round-robin probes in pattern
order), the 1-pair-cluster == CronusSystem exactness guarantee, mixed-kind
end-to-end runs under every router, the topology DSL, and the
decode-offload metrics regression (PPI-finished requests must be counted).
"""
import copy

import numpy as np
import pytest

from repro.cluster import (ClusterRuntime, LeastLoadedRouter,
                           RoundRobinRouter, SessionAffinityRouter,
                           WorkerEndpoint, build_cluster, parse_cluster_spec)
from repro.cluster.router import make_router
from repro.configs import get_config
from repro.core.balancer import Balancer
from repro.core.cronus import build_cronus
from repro.core.engine import Engine, EngineConfig
from repro.core.executor import NullExecutor
from repro.core.predictor import profile_chunked, profile_prefill
from repro.core.request import Request
from repro.serving.hardware import A10, A100, DeviceModel
from repro.serving.simulator import build_system
from repro.serving.trace import make_trace

CFG = get_config("llama3-8b")


def _worker(name: str, num_kv_blocks: int = 1024,
            queue_cap=None) -> WorkerEndpoint:
    eng = Engine(name, CFG,
                 EngineConfig(max_slots=8, num_kv_blocks=num_kv_blocks),
                 DeviceModel(A10, CFG), NullExecutor())
    return WorkerEndpoint(name, eng, queue_cap=queue_cap)


def _req(rid: str, session=None, n: int = 8) -> Request:
    return Request(req_id=rid, prompt=np.zeros(n, np.int32), output_len=4,
                   session=session)


# ---------------------------------------------------------------------------
# router policies
# ---------------------------------------------------------------------------

def test_least_loaded_picks_most_free_kv_blocks():
    small, big = _worker("small", num_kv_blocks=128), _worker("big", 4096)
    router = LeastLoadedRouter()
    assert router.select(_req("r0"), [small, big]) is big
    assert router.select(_req("r0"), [big, small]) is big


def test_least_loaded_prefers_shallow_queue_over_free_blocks():
    deep, shallow = _worker("deep", 4096), _worker("shallow", 128)
    deep.engine.add_request(_req("q0"))
    assert LeastLoadedRouter().select(_req("r0"), [deep, shallow]) is shallow


def test_session_affinity_is_sticky():
    a, b = _worker("a", 4096), _worker("b", 1024)
    router = SessionAffinityRouter()
    first = router.select(_req("r0", session="s1"), [a, b])
    assert first is a           # fallback least-loaded: most free blocks
    # load the home endpoint heavily: a fresh request prefers b ...
    for i in range(4):
        a.engine.add_request(_req(f"q{i}"))
    assert router.select(_req("r1", session="s2"), [a, b]) is b
    # ... but the s1 session stays pinned to its home endpoint
    assert router.select(_req("r2", session="s1"), [a, b]) is a


def test_session_affinity_waits_for_full_home_endpoint():
    a, b = _worker("a", 4096, queue_cap=1), _worker("b", 1024, queue_cap=8)
    router = SessionAffinityRouter()
    assert router.select(_req("r0", session="s1"), [a, b]) is a
    a.engine.add_request(_req("q0"))     # fill a's queue to its cap
    # sticky sessions wait rather than migrate (KV locality)...
    assert router.select(_req("r1", session="s1"), [a, b]) is None
    # ...while other traffic is free to go to b
    assert router.select(_req("r2", session="s9"), [a, b]) is b


def test_weighted_round_robin_pattern_and_skip():
    a, b = _worker("a", queue_cap=8), _worker("b", queue_cap=8)
    router = RoundRobinRouter(weights=[2, 1])
    picks = [router.select(_req(f"r{i}"), [a, b]).name for i in range(6)]
    assert picks == ["a", "a", "b", "a", "a", "b"]
    # a full endpoint is skipped; a fully-blocked cluster returns None
    full = _worker("full", queue_cap=0)
    open_ = _worker("open", queue_cap=2)
    router = RoundRobinRouter()
    assert router.select(_req("r0"), [full, open_]) is open_
    assert RoundRobinRouter().select(_req("r1"), [full]) is None


def test_session_lookahead_avoids_convoying():
    """A sticky head pinned to a full home endpoint must not block the
    unrelated traffic queued behind it: the runtime's bounded lookahead
    (opted into by SessionAffinityRouter) places it elsewhere."""
    from collections import deque
    a, b = _worker("a", 4096, queue_cap=1), _worker("b", 1024, queue_cap=8)
    router = SessionAffinityRouter()
    rt = ClusterRuntime([a, b], router)
    assert router.select(_req("r0", session="s1"), [a, b]) is a
    a.engine.add_request(_req("q0"))          # home endpoint now full
    pending = deque([_req("r1", session="s1"), _req("r2"), _req("r3")])
    rt._dispatch(pending)
    # r1 stays pinned (waiting), r2/r3 flowed to b past it
    assert [r.req_id for r in pending] == ["r1"]
    assert {r.req_id for r in b.engine.queue} == {"r2", "r3"}


def test_session_rebalances_after_repeated_stalls():
    """Regression: a session used to stay pinned to its home endpoint
    FOREVER, even one that never frees up — its requests would defer
    eternally. After ``max_stalls`` consecutive rejections the session
    must re-pin through the fallback policy."""
    a, b = _worker("a", 4096, queue_cap=1), _worker("b", 1024, queue_cap=8)
    router = SessionAffinityRouter(max_stalls=3)
    assert router.select(_req("r0", session="s1"), [a, b]) is a
    a.engine.add_request(_req("q0"))          # home full, and it stays full
    for i in range(router.max_stalls):        # tolerated stalls: wait
        assert router.select(_req(f"r{i+1}", session="s1"), [a, b]) is None
    # one more rejection crosses the threshold: the session migrates to b
    moved = router.select(_req("rX", session="s1"), [a, b])
    assert moved is b
    # ...and the new pin sticks on later selects
    assert router.select(_req("rY", session="s1"), [a, b]) is b


def test_session_rebalances_away_from_overloaded_home():
    """Staleness escape hatch: a home endpoint that is drastically more
    loaded than the best alternative loses the pin immediately — KV
    locality is not worth an unbounded queue."""
    a, b = _worker("a", 4096, queue_cap=None), _worker("b", 1024,
                                                      queue_cap=None)
    router = SessionAffinityRouter(imbalance=4.0)
    assert router.select(_req("r0", session="s1"), [a, b]) is a
    for i in range(6):                        # 6 > 4.0 * (0 + 1)
        a.engine.add_request(_req(f"q{i}"))
    moved = router.select(_req("r1", session="s1"), [a, b])
    assert moved is b
    assert router.select(_req("r2", session="s1"), [a, b]) is b


def test_make_router_registry():
    assert isinstance(make_router("least_loaded"), LeastLoadedRouter)
    with pytest.raises(KeyError):
        make_router("nope")


# ---------------------------------------------------------------------------
# topology spec
# ---------------------------------------------------------------------------

def test_parse_cluster_spec():
    spec = parse_cluster_spec("2xcronus:A100+A10, worker:A30,pp:A100+A10")
    kinds = [(n.kind, n.devices, n.count) for n in spec.nodes]
    assert kinds == [("cronus", ("A100", "A10"), 2),
                     ("worker", ("A30",), 1),
                     ("pp", ("A100", "A10"), 1)]
    assert spec.n_engines == 2 * 2 + 1 + 1
    for bad in ("", "cronus", "cronus:B200", "worker:A100+A10", "3cronus:A10"):
        with pytest.raises(ValueError):
            parse_cluster_spec(bad)


# ---------------------------------------------------------------------------
# cluster end-to-end
# ---------------------------------------------------------------------------

def test_one_pair_cluster_reproduces_cronus_exactly():
    """A 1-pair cluster must produce byte-identical metrics to the
    single-pair CronusSystem facade (same engines, same balancer, same
    event loop) — the backbone of the refactor's no-regression claim."""
    reqs = make_trace(80, seed=3, interval=0.05)
    facade = build_system("cronus", CFG, A100, A10)
    m_facade = facade.run([copy.deepcopy(r) for r in reqs])
    cluster = build_cluster(CFG, "cronus:A100+A10", router="round_robin")
    m_cluster = cluster.run([copy.deepcopy(r) for r in reqs])
    assert m_facade == m_cluster


@pytest.mark.parametrize("router", ["round_robin", "least_loaded", "session"])
def test_mixed_cluster_completes_under_every_router(router):
    reqs = make_trace(60, seed=4, interval=0.02, sessions=8)
    system = build_cluster(CFG, "cronus:A100+A10,worker:A30,disagg_lh:A100+A10",
                           router=router)
    assert len(system.engines) == 5
    m = system.run([copy.deepcopy(r) for r in reqs])
    assert m["completed"] == len(reqs)
    assert m["throughput"] > 0
    # nothing lost, nothing duplicated across endpoints
    done = [r.req_id for r in system.finished()]
    assert sorted(done) == sorted(r.req_id for r in reqs)


def test_multi_pair_scales_throughput():
    reqs = make_trace(120, seed=5, interval=0.0)
    one = build_cluster(CFG, "cronus:A100+A10").run(
        [copy.deepcopy(r) for r in reqs])
    three = build_cluster(CFG, "3xcronus:A100+A10").run(
        [copy.deepcopy(r) for r in reqs])
    assert three["completed"] == one["completed"] == len(reqs)
    assert three["throughput"] > 1.25 * one["throughput"]
    assert three["ttft_p99"] < one["ttft_p99"]


# ---------------------------------------------------------------------------
# decode-offload metrics regression
# ---------------------------------------------------------------------------

def test_offload_finishers_counted_in_metrics():
    """Regression: CronusSystem.run used to aggregate only cpi.finished,
    silently dropping every request that completed on the PPI under
    decode_offload=True."""
    hi, lo = DeviceModel(A100, CFG), DeviceModel(A10, CFG)
    bal = Balancer(profile_prefill(lo), profile_chunked(hi))
    system = build_cronus(CFG, lo, hi,
                          executor_factory=lambda role: NullExecutor(),
                          balancer=bal, max_slots=64, decode_offload=True)
    # tiny CPI pool -> Alg. 1 falls back -> bounded offload to the PPI
    system.cpi.allocator = type(system.cpi.allocator)(num_blocks=200,
                                                      block_size=16)
    reqs = make_trace(40, seed=2, interval=0.0, mean_in=80, mean_out=200,
                      max_in=256, max_out=512)
    m = system.run([copy.deepcopy(r) for r in reqs])
    assert len(system.ppi.finished) > 0          # offload actually fired
    assert m["completed"] == len(reqs)           # ...and none were dropped
    assert m["completed"] == (len(system.ppi.finished)
                              + len(system.cpi.finished))
