"""Cluster-scale KV tier boundaries: demote -> promote round-trips
through the host-memory tier, two-tier invariant accounting, transfer
engine cancellation hygiene, detach-time KV migration conservation, and
host-tier hits that stay token-identical on real paged compute."""
import numpy as np
import pytest

from repro.core.request import ReqState, Request
from repro.kvcache import BlockAllocator, TransferEngine
from repro.serving.api import ServeSpec
from repro.serving.trace import make_trace

BS = 4


def _toks(seed, n):
    return np.random.default_rng(seed).integers(0, 997, n).astype(np.int32)


# ---------------------------------------------------------------------------
# allocator tier boundaries
# ---------------------------------------------------------------------------

def test_demote_promote_roundtrip_preserves_chain():
    """Eviction under pressure demotes refcount-0 cache blocks to the host
    tier (lookup still sees them); a later share promotes them back into
    GPU blocks at refcount 1 with chain hashes intact, and both directions
    accrue PCIe traffic for the engine to charge."""
    a = BlockAllocator(8, BS, prefix_cache=True, host_blocks=16)
    toks = _toks(0, 16)                        # 4 full blocks
    a.allocate("a", 16)
    a.free("a", cache_tokens=toks)
    assert a.lookup_prefix(toks) == 16
    a.check_invariants()

    a.allocate("b", 32)                        # whole pool -> evicts all 4
    assert a.n_demotions == 4
    assert a.host_resident_blocks == 4
    # host entries never inflate the admission signal
    assert a.num_free == 0
    # but the chain is still promise-able across the tier boundary
    assert a.lookup_prefix(toks) == 16
    a.check_invariants()
    a.free("b")                                # preemption-style, no caching

    n = a.share_blocks("c", toks)
    assert n == 16
    assert a.n_promotions == 4
    assert a.host_resident_blocks == 0
    assert len(a.block_table("c")) == 4
    a.check_invariants()                       # refcounts == block tables
    # PCIe traffic: 16 tokens down + 16 back up
    assert a.take_pending_host_transfer_tokens() == 32
    assert a.take_pending_host_transfer_tokens() == 0

    # promoted blocks are ordinary cache blocks again: a second consumer
    # shares them GPU-side, with no further host traffic
    a.free("c", cache_tokens=toks)
    assert a.share_blocks("d", toks) == 16
    assert a.n_promotions == 4
    a.check_invariants()


def test_partial_tail_dropped_on_demote():
    """Only full blocks demote: the cross-tier walk matches full-block
    chain links, so a demoted partial could never be promoted back."""
    a = BlockAllocator(4, BS, prefix_cache=True, host_blocks=8)
    toks = _toks(1, 10)                        # 2 full + 1 partial block
    a.allocate("a", 10)
    a.free("a", cache_tokens=toks)
    assert a.lookup_prefix(toks) == 10         # partial served via CoW

    a.allocate("b", 16)                        # evict all three
    assert a.n_demotions == 2                  # partial dropped, not demoted
    assert a.host_resident_blocks == 2
    assert a.lookup_prefix(toks) == 8          # the partial tail is gone
    a.check_invariants()                       # asserts no partials host-side


def test_host_capacity_evicts_lru_and_breaks_chain():
    """The host tier is bounded: overflow drops the oldest entries. Losing
    a chain's head makes its surviving links unreachable — lookup and
    share degrade to zero rather than resurrect a broken chain."""
    a = BlockAllocator(4, BS, prefix_cache=True, host_blocks=2)
    toks = _toks(2, 16)
    a.allocate("a", 16)
    a.free("a", cache_tokens=toks)
    a.allocate("b", 16)                        # demote 4 into a 2-entry tier
    assert a.n_demotions == 4
    assert a.n_host_evictions == 2             # chain head aged out first
    assert a.host_resident_blocks == 2
    assert a.lookup_prefix(toks) == 0
    a.free("b")
    assert a.share_blocks("c", toks) == 0
    a.check_invariants()


def test_promotion_out_of_blocks_truncates_chain():
    """A share that runs out of GPU blocks mid-promotion keeps the
    contiguous prefix it already placed and drops the rest (no partial
    CoW after a broken chain)."""
    a = BlockAllocator(4, BS, prefix_cache=True, host_blocks=8)
    toks = _toks(3, 16)
    a.allocate("a", 16)
    a.free("a", cache_tokens=toks)
    a.allocate("b", 16)                        # all 4 chain blocks -> host
    a.free("b")
    a.allocate("c", 12)                        # pin 3 blocks; 1 free left
    n = a.share_blocks("d", toks)
    assert n == BS                             # one promotion, then break
    assert a.n_promotions == 1
    assert len(a.block_table("d")) == 1
    assert a.host_resident_blocks == 3
    a.check_invariants()


def test_register_keeps_tiers_disjoint():
    """Content that re-materializes on the GPU while a stale copy sits in
    the host tier drops the host copy: a chain hash resolves in exactly
    one tier (check_invariants enforces the partition)."""
    a = BlockAllocator(8, BS, prefix_cache=True, host_blocks=8)
    toks = _toks(4, 16)
    a.allocate("a", 16)
    a.free("a", cache_tokens=toks)
    a.allocate("b", 32)                        # demote the 4 chain blocks
    assert a.host_resident_blocks == 4
    a.free("b")
    # recompute the same content from scratch (cold prefill elsewhere)
    a.allocate("c", 16)
    a.free("c", cache_tokens=toks)
    assert a.host_resident_blocks == 0         # GPU copy is authoritative
    assert a.n_host_evictions == 4
    assert a.lookup_prefix(toks) == 16
    a.check_invariants()


def test_host_tier_requires_prefix_cache():
    with pytest.raises(ValueError, match="prefix_cache"):
        BlockAllocator(8, BS, prefix_cache=False, host_blocks=4)


# ---------------------------------------------------------------------------
# transfer engine: cancellation leaves both pools clean
# ---------------------------------------------------------------------------

class _FakeRuntime:
    """Collects posted events so the test controls delivery time."""

    def __init__(self):
        self.events = []

    def post(self, time, fn):
        self.events.append((time, fn))

    def fire_all(self):
        for _, fn in self.events:
            fn()
        self.events.clear()


class _Link:
    def transfer_time(self, n_tokens):
        return 0.25


def _req(rid, n=8):
    return Request(req_id=rid, prompt=_toks(5, n), output_len=4,
                   arrival=0.0)


def test_transfer_cancel_midflight_never_delivers():
    rt = _FakeRuntime()
    eng = TransferEngine(rt)
    delivered = []
    h = eng.transfer(_req("x"), src="a", dst="b",
                     deliver=delivered.append, when=1.0, n_tokens=32)
    assert eng.n_inflight == 1
    assert h.cancel()                          # lands between post and drain
    rt.fire_all()
    assert delivered == []
    assert eng.n_inflight == 0
    assert eng.n_cancelled == 1
    assert eng.tokens_moved == 0               # neither pool saw the payload
    assert not h.cancel()                      # already settled


def test_transfer_cancelled_request_state_blocks_delivery():
    rt = _FakeRuntime()
    eng = TransferEngine(rt)
    delivered = []
    r = _req("y")
    eng.transfer(r, src="a", dst="b", deliver=delivered.append,
                 when=1.0, n_tokens=16)
    r.state = ReqState.CANCELLED               # user cancel races delivery
    rt.fire_all()
    assert delivered == []
    assert eng.n_cancelled == 1 and eng.n_inflight == 0


def test_transfer_delivery_and_accounting():
    rt = _FakeRuntime()
    eng = TransferEngine(rt)
    delivered = []
    r = _req("z")
    eng.transfer(r, src="a", dst="b", deliver=delivered.append,
                 when=2.0, n_tokens=48, kind="migration")
    assert eng.cancel("not-a-req") is False
    rt.fire_all()
    assert [q.req_id for q in delivered] == ["z"]
    s = eng.stats()
    assert s["n_transfers"] == 1 and s["n_cancelled"] == 0
    assert s["tokens_moved"] == 48 and s["tokens_migration"] == 48


def test_transfer_link_charge_bumps_ready_time():
    rt = _FakeRuntime()
    eng = TransferEngine(rt)
    r = _req("w")
    r.ready_time = 0.0
    eng.transfer(r, src="a", dst="b", deliver=lambda q: None, when=1.0,
                 n_tokens=8, device_model=_Link(), charge="link",
                 kind="prefix_fetch")
    assert r.ready_time == pytest.approx(1.25)
    assert rt.events[0][0] == pytest.approx(1.25)
    with pytest.raises(ValueError, match="charge"):
        eng.transfer(r, src="a", dst="b", deliver=lambda q: None,
                     when=0.0, charge="teleport")


# ---------------------------------------------------------------------------
# detach-time migration: conservation through the transfer engine
# ---------------------------------------------------------------------------

def _terminal_ids(service):
    return ([r.req_id for ep in service.endpoints for r in ep.finished()]
            + [r.req_id for r in service.runtime.retired])


def _detach_run(migrate):
    service = ServeSpec(cluster="2xworker:A10").build()
    for r in make_trace(40, seed=0, interval=0.05):
        service.submit(r)
    service.step_until(2.0)
    victim = max(service.endpoints,
                 key=lambda ep: ep.stats().queue_depth)
    assert any(r is not None for e in victim.engines for r in e.slots)
    service.detach_endpoint(victim.name, migrate=migrate)
    for ep in service.endpoints:
        for eng in ep.engines:
            eng.allocator.check_invariants()
    m = service.drain()
    assert m["completed"] == 40
    ids = _terminal_ids(service)
    assert len(ids) == len(set(ids)) == 40
    return service.runtime.transfers.stats()


def test_detach_migrate_moves_kv_and_conserves_requests():
    s = _detach_run(migrate=True)
    assert s.get("tokens_migration", 0) > 0    # residents moved with KV
    assert s["n_inflight"] == 0


def test_detach_migrate_false_forces_recompute():
    s = _detach_run(migrate=False)
    assert s.get("tokens_migration", 0) == 0   # drained by recompute only


# ---------------------------------------------------------------------------
# real paged compute: a host-tier hit is token-identical
# ---------------------------------------------------------------------------

def test_host_tier_hit_token_identical_paged():
    """Real compute through the full demote -> promote cycle: r0 seeds the
    cache, a filler's allocation pressure spills the shared chain to the
    host tier (the executor's on_demote hook saves the physical KV rows),
    and r1's share promotes it back — decoding exactly the tokens of a
    cold run, so the restored rows must be bit-faithful."""
    import jax

    from repro.configs import get_config
    from repro.models import build_model  # noqa: F401 (built by the spec)

    cfg = get_config("llama3-8b", smoke=True)
    rng = np.random.default_rng(11)
    shared = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    tail0 = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
    tail1 = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
    filler = rng.integers(0, cfg.vocab_size, 33).astype(np.int32)

    def reqs():
        return [Request(req_id="r0", prompt=np.concatenate([shared, tail0]),
                        output_len=6, arrival=0.0),
                Request(req_id="f0", prompt=filler.copy(), output_len=6,
                        arrival=5.0),
                Request(req_id="r1", prompt=np.concatenate([shared, tail1]),
                        output_len=6, arrival=10.0)]

    def run(cluster):
        spec = ServeSpec(cluster=cluster, smoke=True, executor="paged",
                         s_kv=64, max_slots=4, block_size=BS,
                         max_batched_tokens=16, num_kv_blocks=12)
        svc = spec.build()
        svc.run(reqs())
        eng = svc.engines[0]
        toks = {r.req_id: list(r.generated) for r in eng.finished}
        assert len(toks) == 3
        return toks, eng.allocator

    cold, _ = run("worker:A100")
    warm, alloc = run("worker:A100@cache@host")
    # the cycle actually happened: the 4-block shared chain went down...
    assert alloc.n_demotions >= 4
    # ...and came back up when r1 shared it
    assert alloc.n_promotions >= 4
    assert warm == cold


# ---------------------------------------------------------------------------
# spec surface
# ---------------------------------------------------------------------------

def test_spec_refuses_host_tier_without_cache():
    with pytest.raises(ValueError, match="host"):
        ServeSpec(host_kv_blocks=64)
    with pytest.raises(ValueError, match="cache"):
        ServeSpec(cluster="worker:A10@host").build()
    with pytest.raises(ValueError, match="host_kv_blocks"):
        ServeSpec(host_kv_blocks=-1, prefix_cache=True)
