"""Elastic autoscaling: live attach/detach with drain-by-recompute,
router hygiene under membership change, EndpointStats window signals,
policy/inventory spec round-trips, the SLO-driven scaling loop, and the
inertness contract (no autoscaler => nothing changes)."""
import argparse
import json

import pytest

from repro.autoscale import (Autoscaler, DeviceInventory, DeviceLedger,
                             EndpointTemplate, UNIT_COST, build_endpoint,
                             default_templates, endpoint_devices,
                             parse_autoscale)
from repro.cluster import build_cluster
from repro.cluster.router import (PrefixAffinityRouter, RoundRobinRouter,
                                  SessionAffinityRouter)
from repro.configs import get_config
from repro.serving.api import ServeSpec
from repro.serving.trace import make_trace
from repro.workloads import OpenLoopDriver

CFG = get_config("llama3-8b")

# the closed-loop aggregate's exact key set since the seed — feature keys
# (cancelled / goodput / queueing_*) appear only when their feature is
# used, and autoscaling must not add any
SEED_KEYS = {"throughput", "ttft_p50", "ttft_p99", "tbt_p50", "tbt_p99",
             "completed", "makespan"}


def _check_clean(service):
    for ep in service.endpoints:
        for eng in ep.engines:
            eng.allocator.check_invariants()


def _terminal_ids(service):
    return ([r.req_id for ep in service.endpoints for r in ep.finished()]
            + [r.req_id for r in service.runtime.retired])


# ---------------------------------------------------------------------------
# live membership: detach drains by recompute, attach joins mid-run
# ---------------------------------------------------------------------------

def test_detach_mid_decode_loses_no_request():
    service = ServeSpec(cluster="2xworker:A10").build()
    reqs = make_trace(40, seed=0, interval=0.05)
    for r in reqs:
        service.submit(r)
    service.step_until(2.0)          # decodes underway on both workers
    victim = max(service.endpoints, key=lambda ep: ep.stats().queue_depth)
    assert any(r is not None for e in victim.engines for r in e.slots)
    service.detach_endpoint(victim.name)
    assert victim.name not in [ep.name for ep in service.endpoints]
    _check_clean(service)
    m = service.drain()
    assert m["completed"] == 40
    ids = _terminal_ids(service)
    assert len(ids) == len(set(ids)) == 40   # nothing lost, nothing doubled


def test_detach_mid_ppi_prefill_recomputes_handoffs():
    service = ServeSpec(cluster="cronus:A100+A10,worker:A10").build()
    reqs = make_trace(30, seed=3, interval=0.02)
    for r in reqs:
        service.submit(r)
    pair = service.endpoints[0]
    for _ in range(4):
        service.step()
    assert pair._in_ppi, "test needs an in-flight PPI handoff"
    service.detach_endpoint(pair.name)
    assert not pair._in_ppi and not pair._offloaded
    _check_clean(service)
    m = service.drain()
    assert m["completed"] == 30
    ids = _terminal_ids(service)
    assert len(ids) == len(set(ids)) == 30
    # displaced requests recompute from the full prompt: TTFT still sane
    for ep in service.endpoints:
        for r in ep.finished():
            assert r.metrics.first_token_time >= r.arrival


def test_detach_with_queued_requests_requeues_in_arrival_order():
    service = ServeSpec(cluster="2xworker:A10").build()
    reqs = make_trace(24, seed=1, interval=0.01)
    for r in reqs:
        service.submit(r)
    service.step()                   # dispatch; queues now hold most work
    victim = max(service.endpoints, key=lambda ep: ep.stats().queue_depth)
    assert any(e.queue for e in victim.engines)
    service.detach_endpoint(victim.name)
    arrivals = [r.arrival for r in service._pending]
    assert arrivals == sorted(arrivals)
    m = service.drain()
    assert m["completed"] == 24


def test_detach_finished_endpoint_counts_metrics_once():
    service = ServeSpec(cluster="2xworker:A10").build()
    reqs = make_trace(20, seed=4, interval=0.1)
    m_before = service.run(reqs)
    assert m_before["completed"] == 20
    kept = service.detach_endpoint(service.endpoints[0].name)
    assert kept.n_finished() == len(
        [r for r in service.runtime.retired])  # moved, not copied
    m_after = service.metrics()
    assert m_after == m_before        # bit-identical despite the detach


def test_runtime_detach_guards():
    service = ServeSpec(cluster="2xworker:A10").build()
    with pytest.raises(KeyError):
        service.runtime.detach_endpoint("no-such-endpoint")
    reqs = make_trace(8, seed=0, interval=0.0)
    for r in reqs:
        service.submit(r)
    service.step()
    busy = max(service.endpoints, key=lambda ep: ep.stats().queue_depth)
    with pytest.raises(RuntimeError, match="pending"):
        service.runtime.detach_endpoint(busy.name, pending=None)
    service.drain()


def test_attach_syncs_clocks_and_serves():
    service = ServeSpec(cluster="worker:A10").build()
    reqs = make_trace(24, seed=2, interval=0.05)
    for r in reqs:
        service.submit(r)
    service.step_until(1.0)
    now = service.now
    assert now > 0.0
    late = build_endpoint(CFG, "worker:A10", "late-worker",
                          **service.build_kw)
    service.attach_endpoint(late)
    assert all(e.clock >= now for e in late.engines)  # no time travel
    with pytest.raises(ValueError, match="duplicate"):
        service.attach_endpoint(
            build_endpoint(CFG, "worker:A10", "late-worker",
                           **service.build_kw))
    m = service.drain()
    assert m["completed"] == 24
    assert late.n_finished() > 0     # the joiner actually took load


# ---------------------------------------------------------------------------
# router hygiene under membership change
# ---------------------------------------------------------------------------

def _workers(n):
    return list(build_cluster(CFG, f"{n}xworker:A10").endpoints)


def test_round_robin_survives_membership_change():
    eps = _workers(2)
    rr = RoundRobinRouter(weights=[3, 1])
    req = make_trace(1, seed=0)[0]
    assert rr.select(req, eps) is not None
    eps3 = _workers(3)
    rr.on_membership_change(eps3)
    assert rr.weights is None        # fleet-size weights cannot remap
    picked = {rr.select(make_trace(1, seed=i)[0], eps3).name
              for i in range(6)}
    assert picked == {ep.name for ep in eps3}   # uniform rotation


def test_session_affinity_rehomes_after_detach():
    eps = _workers(2)
    router = SessionAffinityRouter()
    reqs = make_trace(4, seed=0, sessions=1)    # one shared session
    home = router.select(reqs[0], eps)
    assert router._table[reqs[0].session] is home
    survivors = [ep for ep in eps if ep is not home]
    router.on_membership_change(survivors)
    assert reqs[0].session not in router._table
    rehomed = router.select(reqs[1], survivors)
    assert rehomed is survivors[0]   # re-pinned through the fallback


def test_prefix_affinity_history_keyed_by_name_and_pruned():
    eps = _workers(2)
    router = PrefixAffinityRouter()
    req = make_trace(1, seed=7)[0]
    first = router.select(req, eps)
    assert first.name in router._history
    survivors = [ep for ep in eps if ep is not first]
    router.on_membership_change(survivors)
    assert first.name not in router._history    # its KV left with it
    # a re-attached endpoint under the same name starts cold
    fresh = _workers(2)[0]
    fresh.name = first.name
    roster = survivors + [fresh]
    bs = fresh.engines[-1].ecfg.block_size
    hashes = router._prompt_hashes(req, bs)
    assert router._history_match(fresh.name, hashes, bs) == 0
    assert router.select(make_trace(1, seed=8)[0], roster) is not None


# ---------------------------------------------------------------------------
# EndpointStats window signals
# ---------------------------------------------------------------------------

def test_busy_fraction_and_oldest_queued_age():
    service = ServeSpec(cluster="worker:A10", max_slots=4).build()
    ep = service.endpoints[0]
    s0 = ep.stats()
    assert s0.busy_frac == 0.0 and s0.oldest_queued_age == 0.0
    for r in make_trace(12, seed=0, interval=0.0):
        service.submit(r)
    for _ in range(6):
        service.step()
    s = ep.stats()
    assert 0.0 < s.busy_frac <= 1.0
    assert s.oldest_queued_age > 0.0         # backlog aging behind slots
    service.drain()
    assert ep.stats().oldest_queued_age == 0.0   # queues empty again


def test_metrics_keys_unchanged_without_autoscaler():
    """The inertness contract: a fixed-fleet service exposes exactly the
    seed's aggregate keys — autoscaling machinery must add nothing."""
    service = ServeSpec(approach="cronus").build()
    assert service.autoscaler is None
    m = service.run(make_trace(15, seed=1, interval=0.1))
    assert set(m) == SEED_KEYS


# ---------------------------------------------------------------------------
# inventory / templates / ledger
# ---------------------------------------------------------------------------

def test_inventory_parse_take_put_roundtrip():
    inv = DeviceInventory.parse("A100:1,A10:4")
    assert inv.total == 5 and inv.spec == "A10:4,A100:1"
    assert DeviceInventory.parse(inv.spec).counts == inv.counts
    assert inv.can_build(("A100", "A10"))
    inv.take(("A100", "A10"))
    assert not inv.can_build(("A100",)) and inv.counts == {"A10": 3}
    with pytest.raises(ValueError, match="cannot supply"):
        inv.take(("A100",))
    inv.put(("A100",))
    assert inv.can_build(("A100",))
    for bad in ("A100", "H100:2", "A10:x"):
        with pytest.raises(ValueError):
            DeviceInventory.parse(bad)


def test_templates_devices_costs_and_defaults():
    t = EndpointTemplate("cronus:A100+A10", capacity_qps=5.7)
    assert t.kind == "cronus" and t.devices == ("A100", "A10")
    assert t.cost_rate == pytest.approx(UNIT_COST["A100"] + UNIT_COST["A10"])
    with pytest.raises(ValueError, match="one node"):
        EndpointTemplate("2xworker:A10", capacity_qps=1.0)
    with pytest.raises(ValueError, match="capacity_qps"):
        EndpointTemplate("worker:A10", capacity_qps=0.0)
    nodes = {t.node for t in
             default_templates(DeviceInventory.parse("A100:1,A10:2"))}
    assert nodes == {"worker:A100", "worker:A10", "cronus:A100+A10"}
    # measured capacities override the FLOPS prior
    (tpl,) = [t for t in default_templates(
        DeviceInventory.parse("A10:1"),
        capacity_qps={"worker:A10": 2.5}) if t.node == "worker:A10"]
    assert tpl.capacity_qps == 2.5


def test_ledger_prices_open_and_closed_leases():
    led = DeviceLedger()
    led.open("a", ("A100", "A10"), 0.0)
    led.open("b", ("A10",), 5.0)
    led.close("b", 15.0)
    secs = led.device_seconds(20.0)
    assert secs["A100"] == pytest.approx(20.0)
    assert secs["A10"] == pytest.approx(30.0)     # 20 open + 10 closed
    assert led.device_cost(20.0) == pytest.approx(
        20.0 * UNIT_COST["A100"] + 30.0 * UNIT_COST["A10"])
    with pytest.raises(ValueError, match="open lease"):
        led.open("a", ("A10",), 1.0)


def test_endpoint_devices_reads_pair_and_pipeline():
    pair = build_cluster(CFG, "cronus:A100+A10").endpoints[0]
    assert sorted(endpoint_devices(pair)) == ["A10", "A100"]
    pp = ServeSpec(approach="pp").build().endpoints[0]
    assert sorted(endpoint_devices(pp)) == ["A10", "A100"]


# ---------------------------------------------------------------------------
# policy spec round-trip + ServeSpec integration
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [
    "slo",
    "slo:goodput>=0.85",
    "slo:goodput>=0.9:cooldown=5",
    "slo:cooldown=2:window=6:up_age=1.5:down_busy=0.2:min=2",
    "slo:eval=0.5:spinup=3:ttft=4:tbt=0.1:down_headroom=0.7",
])
def test_policy_spec_roundtrip(spec):
    p = parse_autoscale(spec)
    assert parse_autoscale(p.spec) == p


@pytest.mark.parametrize("bad,msg", [
    ("schedule:9to5", "unknown autoscale policy kind"),
    ("slo:warp=9", "bad autoscale clause"),
    ("slo:cooldown", "bad autoscale clause"),
    ("slo:cooldown=fast", "bad number"),
    ("slo:goodput>=0", "goodput target"),
    ("slo:min=0", "min_endpoints"),
    ("slo:down_busy=1.0", "down_busy"),
    ("slo:cooldown=1:cooldown=2", "duplicate"),
    ("slo:", "empty clause"),
])
def test_policy_spec_rejects(bad, msg):
    with pytest.raises(ValueError, match=msg):
        parse_autoscale(bad)


def test_serve_spec_autoscale_roundtrips_and_refusals():
    spec = ServeSpec(approach="cronus", arrival="ramp:1:8:120",
                     autoscale="slo:goodput>=0.9:cooldown=5",
                     inventory="A100:1,A10:4")
    assert ServeSpec.from_dict(json.loads(json.dumps(spec.to_dict()))) == spec
    ap = argparse.ArgumentParser()
    ServeSpec.add_cli_args(ap)
    cli = ServeSpec.from_cli(ap.parse_args(
        ["--arrival", "ramp:1:8:120", "--autoscale",
         "slo:goodput>=0.9:cooldown=5", "--inventory", "A100:1,A10:4"]))
    assert cli == spec
    with pytest.raises(ValueError, match="non-empty device inventory"):
        ServeSpec(autoscale="slo")
    with pytest.raises(ValueError, match="non-empty device inventory"):
        ServeSpec(autoscale="slo", inventory="")
    with pytest.raises(ValueError, match="inventory without autoscale"):
        ServeSpec(inventory="A10:4")
    with pytest.raises(ValueError, match="simulation-only"):
        ServeSpec(autoscale="slo", inventory="A10:1", executor="real",
                  s_kv=64)
    with pytest.raises(ValueError, match="unknown autoscale"):
        ServeSpec(autoscale="magic", inventory="A10:1")
    with pytest.raises(ValueError, match="unknown device"):
        ServeSpec(autoscale="slo", inventory="H100:8")


# ---------------------------------------------------------------------------
# the scaling loop end-to-end
# ---------------------------------------------------------------------------

def test_autoscaler_scales_up_under_ramp_and_loses_nothing():
    spec = ServeSpec(approach="cronus", arrival="ramp:1:8:120",
                     autoscale="slo:goodput>=0.9:cooldown=10",
                     inventory="A100:1,A10:4")
    reqs = make_trace(300, seed=0, arrival=spec.arrival,
                      vocab_size=CFG.vocab_size)
    service = spec.build()
    assert service.autoscaler is not None
    driver = OpenLoopDriver(service)
    driver.run(reqs)
    m = driver.metrics(5.0, 0.20)
    assert m["completed"] == 300
    rep = service.autoscaler.report(service.now)
    assert rep["n_scale_ups"] >= 1
    assert rep["device_cost"] > 0.0
    assert rep["final_endpoints"] == len(service.endpoints)
    # every scale-up consumed real inventory and opened a lease
    used = [e for e in rep["events"] if e["action"] == "scale_up"]
    assert all(e["endpoint"].startswith("as") for e in used)
    _check_clean(service)
    ids = _terminal_ids(service)
    assert len(ids) == len(set(ids)) == 300


def test_autoscaler_scales_down_idle_capacity():
    spec = ServeSpec(cluster="2xworker:A10", arrival="poisson:0.4",
                     autoscale="slo:cooldown=2:down_busy=0.9:min=1",
                     inventory="A10:1")
    reqs = make_trace(40, seed=5, arrival=spec.arrival,
                      vocab_size=CFG.vocab_size)
    service = spec.build()
    driver = OpenLoopDriver(service)
    driver.run(reqs)
    assert driver.metrics()["completed"] == 40
    scaler = service.autoscaler
    rep = scaler.report(service.now)
    assert rep["n_scale_downs"] >= 1
    assert len(service.endpoints) >= 1           # never below the floor
    # the shed device went back on the rack, and its lease closed
    assert scaler.inventory.counts["A10"] == 1 + rep["n_scale_downs"] - \
        rep["n_scale_ups"]
    secs = scaler.ledger.device_seconds(service.now)
    assert secs["A10"] < 2 * service.now + 1e-9  # not billed past detach
    ids = _terminal_ids(service)
    assert len(ids) == len(set(ids)) == 40


def test_autoscaler_respects_empty_inventory_and_cooldown():
    inv = DeviceInventory.parse("A10:1")
    pol = parse_autoscale("slo:cooldown=1000")
    spec = ServeSpec(cluster="worker:A10", arrival="poisson:6")
    service = spec.build()
    scaler = Autoscaler(inv, policy=pol)
    service.attach_autoscaler(scaler)
    reqs = make_trace(80, seed=0, arrival="poisson:6",
                      vocab_size=CFG.vocab_size)
    OpenLoopDriver(service).run(reqs)
    rep = scaler.report(service.now)
    # one action fits in the budget; the cooldown blocks every follow-up
    assert rep["n_scale_ups"] + rep["n_scale_downs"] <= 1
