"""PagedRealExecutor: block-pool KV driven by the engine's block tables.

Three layers of evidence that paging is a pure layout change:

  * kernel properties — the paged decode kernel matches the jnp reference
    under ragged context lengths, partial last pages and both page sizes
    the executors use, and is invariant to block-table padding ids;
  * token equivalence — every approach x arrival pattern produces the
    same token streams whether KV lives in dense per-slot buffers
    (RealExecutor) or in the shared block pool (PagedRealExecutor);
  * the features the slot layout cannot do — prefix-cache hits and CoW
    divergence on real compute — leave tokens identical to cold runs.

Compile hygiene rides along: a full trace replay compiles a fixed,
asserted number of (bucket, batch) shapes, and a second identical wave
compiles nothing new.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.executor import PagedRealExecutor, RealExecutor
from repro.core.request import Request
from repro.models import build_model
from repro.serving.api import ServeSpec
from repro.serving.hardware import A100, A30
from repro.serving.simulator import APPROACHES, build_system

S_KV, SLOTS, CHUNK, BLOCK = 128, 4, 16, 4
# identical KV pool for slot and paged runs: the Balancer and admission
# gate on allocator.num_free, so token equivalence needs both runs to
# see the same block budget
NBLK = SLOTS * (S_KV // BLOCK)
LENS = [(17, 5), (33, 8), (9, 4), (41, 6), (25, 3)]


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama3-8b", smoke=True)
    model = build_model(cfg, exact_moe=True)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(42)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n, _ in LENS]
    return cfg, model, params, prompts


def _reqs(prompts, staggered=False):
    reqs = [Request(req_id=f"r{i}", prompt=prompts[i].copy(),
                    output_len=LENS[i][1], arrival=0.0)
            for i in range(len(LENS))]
    if staggered:
        for i, r in enumerate(reqs):
            r.arrival = i * 0.5
            r.metrics.arrival = r.arrival
    return reqs


def _run(kind, cfg, model, params, prompts, approach, staggered):
    if kind == "real":
        def factory(role):
            return RealExecutor(model, params, max_slots=SLOTS, s_kv=S_KV,
                                chunk_pad=CHUNK)
    else:
        def factory(role):
            return PagedRealExecutor(model, params)
    system = build_system(approach, cfg, A100, A30,
                          executor_factory=factory, max_slots=SLOTS,
                          block_size=BLOCK, max_batched_tokens=CHUNK,
                          num_kv_blocks=NBLK, executor=kind)
    res = system.run(_reqs(prompts, staggered))
    assert res["completed"] == len(LENS)
    if hasattr(system, "engines"):               # DPSystem
        engines = system.engines
    elif hasattr(system, "engine"):              # PPSystem
        engines = [system.engine]
    else:                                        # CronusSystem
        engines = [system.ppi, system.cpi]
    toks, parts = {}, {}
    for e in engines:
        for r in e.finished:
            toks.setdefault(r.req_id, list(r.generated))
            parts.setdefault(r.req_id, r.partial_len)
    assert len(toks) == len(LENS)
    return toks, parts


# ---------------------------------------------------------------------------
# kernel properties
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("page", [4, 8])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_paged_decode_kernel_ragged(page, seed):
    """Pallas paged decode == jnp reference under ragged context lengths
    with partial last pages (len % page != 0 for most rows)."""
    from repro.kernels import (paged_decode_attention_pallas,
                               paged_decode_attention_ref)
    rng = np.random.default_rng(seed)
    b, h, kv, d, pages, maxp = 4, 4, 2, 32, 24, 6
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, h, d))
    kp = jax.random.normal(ks[1], (pages, page, kv, d))
    vp = jax.random.normal(ks[2], (pages, page, kv, d))
    bt = np.asarray(rng.integers(0, pages, (b, maxp)), np.int32)
    # ragged: at least one full-page row, the rest partial last pages
    cl = np.asarray([maxp * page]
                    + list(rng.integers(1, maxp * page, b - 1)), np.int32)
    want = paged_decode_attention_ref(q, kp, vp, bt, cl)
    got = paged_decode_attention_pallas(q, kp, vp, bt, cl, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_paged_decode_padding_id_invariance():
    """Table entries past ceil(context_len / page) are dead: any in-range
    page id there (the executor pads with the trash page) must not change
    the output — masking is by context length, never by id."""
    from repro.kernels import paged_decode_attention_ref
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    b, h, kv, d, pages, page, maxp = 2, 4, 2, 32, 16, 4, 4
    q = jax.random.normal(ks[0], (b, h, d))
    kp = jax.random.normal(ks[1], (pages, page, kv, d))
    vp = jax.random.normal(ks[2], (pages, page, kv, d))
    cl = np.asarray([5, 9], np.int32)           # 2 and 3 live pages
    bt = np.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], np.int32)
    base = np.asarray(paged_decode_attention_ref(q, kp, vp, bt, cl))
    for junk in (0, pages - 1):
        bt2 = bt.copy()
        bt2[0, 2:] = junk                        # dead tail of row 0
        bt2[1, 3:] = junk                        # dead tail of row 1
        got = np.asarray(paged_decode_attention_ref(q, kp, vp, bt2, cl))
        np.testing.assert_array_equal(got, base)


# ---------------------------------------------------------------------------
# token equivalence: paged == slot on every approach x arrival pattern
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("staggered", [False, True],
                         ids=["maxtput", "staggered"])
@pytest.mark.parametrize("approach", APPROACHES)
def test_paged_matches_slot_tokens(setup, approach, staggered):
    """Token equivalence matrix. Exact token equality is asserted for
    every cell whose chunk boundaries are arrival-independent (all five
    approaches at maxtput; dp/pp/disagg staggered — FixedBalancer pins
    the split to the input length). cronus+staggered chunk boundaries
    depend on arrival-time CPI stats, which test_system.py documents as
    compile-cache-sensitive on CPU: near-flat smoke-model logits make a
    1-token chunk's robust-greedy pick borderline, so there we assert
    the structure (same balancer splits, same stream lengths) and leave
    exact-token checks to the arrival-independent cells."""
    cfg, model, params, prompts = setup
    slot, s_parts = _run("real", cfg, model, params, prompts, approach,
                         staggered)
    paged, p_parts = _run("paged", cfg, model, params, prompts, approach,
                          staggered)
    assert p_parts == s_parts                  # identical balancer splits
    if approach == "cronus" and staggered:
        assert {k: len(v) for k, v in paged.items()} == \
               {k: len(v) for k, v in slot.items()}
    else:
        assert paged == slot


# ---------------------------------------------------------------------------
# what only the paged layout can do on real compute
# ---------------------------------------------------------------------------

def _cache_reqs(vocab):
    rng = np.random.default_rng(7)
    # 26 % BLOCK != 0 so the cache hit shares a partial block -> CoW copy
    shared = rng.integers(0, vocab, 26).astype(np.int32)
    tails = [rng.integers(0, vocab, n).astype(np.int32) for n in (9, 13, 5)]
    return [Request(req_id=f"c{i}", prompt=np.concatenate([shared, t]),
                    output_len=6, arrival=float(i))
            for i, t in enumerate(tails)]


def test_paged_prefix_cache_cow_divergence(setup):
    """Prefix-cache hits + CoW divergence on REAL compute: the cached run
    skips prefill work (cached_prefix_tokens > 0) yet decodes the exact
    tokens of the cold run — including past the shared prefix, where each
    request's KV diverges in its own CoW copy of the partial block."""
    cfg, *_ = setup

    def run(cluster):
        spec = ServeSpec(cluster=cluster, smoke=True, executor="paged",
                         s_kv=64, max_slots=SLOTS, block_size=BLOCK,
                         max_batched_tokens=CHUNK)
        svc = spec.build()
        svc.run(_cache_reqs(cfg.vocab_size))
        eng = svc.engines[0]
        toks = {r.req_id: list(r.generated) for r in eng.finished}
        reused = sum(r.metrics.cached_prefix_tokens for r in eng.finished)
        return toks, reused

    cold, reused_cold = run("worker:A100")
    warm, reused_warm = run("worker:A100@cache")
    assert reused_cold == 0
    assert reused_warm > 0
    assert warm == cold


def test_real_refuses_prefix_cache_paged_lifts_it():
    with pytest.raises(ValueError, match="paged"):
        ServeSpec(smoke=True, executor="real", s_kv=64, prefix_cache=True)
    spec = ServeSpec(smoke=True, executor="paged", s_kv=64,
                     prefix_cache=True)          # no raise
    assert spec.effective_num_kv_blocks() == spec.max_slots * (64 // 16)
    # and the new fields survive the JSON round-trip
    spec = ServeSpec(smoke=True, executor="paged", s_kv=64,
                     num_kv_blocks=80)
    assert ServeSpec.from_dict(spec.to_dict()) == spec
    with pytest.raises(ValueError, match="num_kv_blocks"):
        ServeSpec(smoke=True, executor="null", num_kv_blocks=80)


# ---------------------------------------------------------------------------
# compile hygiene
# ---------------------------------------------------------------------------

def test_paged_compile_budget(setup):
    """A full trace costs a bounded number of compiled (bucket, batch)
    shapes, and an identical second wave compiles NOTHING new — every
    dispatch hits the pow2-bucket cache."""
    cfg, model, params, prompts = setup
    spec = ServeSpec(smoke=True, approach="cronus", hi="A100", lo="A30",
                     executor="paged", s_kv=S_KV, max_slots=SLOTS,
                     block_size=BLOCK, max_batched_tokens=CHUNK,
                     num_kv_blocks=NBLK)
    svc = spec.build(model=model, params=params)
    svc.run(_reqs(prompts))
    stats = {e.name: e.executor.compile_stats() for e in svc.engines}
    for name, st in stats.items():
        assert st["total_shapes"] <= 12, (name, st)
    wave2 = [Request(req_id=f"w{i}", prompt=prompts[i].copy(),
                     output_len=LENS[i][1], arrival=0.0)
             for i in range(len(LENS))]
    svc.run(wave2)
    after = {e.name: e.executor.compile_stats() for e in svc.engines}
    for name in stats:
        assert after[name]["total_shapes"] == stats[name]["total_shapes"], (
            name, stats[name], after[name])
        assert after[name]["dispatches"] > stats[name]["dispatches"]


# ---------------------------------------------------------------------------
# KV transfer payloads (Cronus PPI -> CPI)
# ---------------------------------------------------------------------------

class _StubEngine:
    """The three attributes attach_engine / the executor call sites read."""

    def __init__(self):
        from repro.core.engine import EngineConfig
        from repro.kvcache.allocator import BlockAllocator
        self.ecfg = EngineConfig(max_batched_tokens=CHUNK, max_slots=SLOTS,
                                 block_size=BLOCK, num_kv_blocks=NBLK,
                                 executor="paged")
        self.allocator = BlockAllocator(NBLK, BLOCK)
        self.slots = [None] * SLOTS

    def place(self, slot, req_id, n_tokens):
        import types
        self.allocator.allocate(req_id, n_tokens)
        self.slots[slot] = types.SimpleNamespace(req_id=req_id)


def test_extract_kv_payload_bounded(setup):
    """Regression: extract_kv must copy only `upto` tokens — the Cronus
    transfer payload is sized by actual context, not capacity. The slot
    executor used to ship the full padded S_KV width; the paged payload
    is block-granular (ceil(upto / page) pages)."""
    cfg, model, params, prompts = setup
    upto = 17
    ex = RealExecutor(model, params, max_slots=SLOTS, s_kv=S_KV,
                      chunk_pad=CHUNK)
    ex.prefill_chunk(0, prompts[0][:upto], 0, False)
    payload = ex.extract_kv(0, upto)
    seq_keys = [k for k in payload["stack"] if k in ("k", "v", "ckv", "kpe")]
    assert seq_keys
    for key in seq_keys:
        assert payload["stack"][key].shape[1] == upto, (
            key, payload["stack"][key].shape, "payload must be `upto`-"
            "bounded, not the padded slot width S_KV")

    px = PagedRealExecutor(model, params)
    eng = _StubEngine()
    px.attach_engine(eng)
    eng.place(0, "p0", upto)
    for lo in range(0, upto, CHUNK):
        hi = min(lo + CHUNK, upto)
        px.prefill_chunk(0, prompts[0][lo:hi], lo, False)
    pp = px.extract_kv(0, upto)
    n_pages = -(-upto // BLOCK)
    assert pp["_upto"] == upto and pp["_page"] == BLOCK
    assert pp["k_pages"].shape == (model.n_stack, n_pages, BLOCK,
                                   cfg.n_kv_heads, cfg.head_dim)
    assert pp["v_pages"].shape == pp["k_pages"].shape


def test_paged_extract_inject_roundtrip(setup):
    """extract_kv -> inject_kv across two paged executors (the PPI->CPI
    handoff) lands the source KV rows exactly in the destination pool
    positions the destination's own block table assigns."""
    cfg, model, params, prompts = setup
    upto = 9
    src, dst = PagedRealExecutor(model, params), PagedRealExecutor(model,
                                                                   params)
    se, de = _StubEngine(), _StubEngine()
    src.attach_engine(se)
    dst.attach_engine(de)
    se.place(0, "s0", upto)
    de.place(2, "d0", upto)                  # different slot, own table
    src.prefill_chunk(0, prompts[0][:upto], 0, False)
    dst.inject_kv(2, src.extract_kv(0, upto), upto)

    st = se.allocator.block_table("s0")
    dt = de.allocator.block_table("d0")
    sk = np.asarray(src.k_pool).reshape(model.n_stack, -1,
                                        cfg.n_kv_heads, cfg.head_dim)
    dk = np.asarray(dst.k_pool).reshape(model.n_stack, -1,
                                        cfg.n_kv_heads, cfg.head_dim)
    for p in range(upto):
        s_idx = st[p // BLOCK] * BLOCK + p % BLOCK
        d_idx = dt[p // BLOCK] * BLOCK + p % BLOCK
        np.testing.assert_array_equal(dk[:, d_idx], sk[:, s_idx])
