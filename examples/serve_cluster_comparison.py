"""End-to-end driver: replay an Azure-style trace against all five serving
approaches on a simulated A100+A10 cluster (paper §5 conditions: 1000
conversation requests, mean in 1014 / out 247) and print the Table-2/Fig-4
style comparison — then scale out to a multi-pair cluster and compare the
three request routers.

  PYTHONPATH=src python examples/serve_cluster_comparison.py [--n 1000]
"""
import argparse
import copy
import sys

sys.path.insert(0, "src")

from repro.cluster import build_cluster
from repro.cluster.router import ROUTERS
from repro.configs import get_config
from repro.serving.hardware import A10, A100
from repro.serving.simulator import APPROACHES, compare_all
from repro.serving.trace import make_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1000)
    ap.add_argument("--arch", default="llama3-8b")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    print(f"== max throughput ({args.n} requests, all at t=0), "
          f"{args.arch} on A100+A10 ==")
    reqs = make_trace(args.n, seed=0, interval=0.0)
    res = compare_all(cfg, A100, A10, reqs)
    print(f"{'approach':12s} {'tput(req/s)':>12s} {'ttft_p99(s)':>12s} "
          f"{'tbt_p99(ms)':>12s}")
    for a in APPROACHES:
        m = res[a]
        print(f"{a:12s} {m['throughput']:12.2f} {m['ttft_p99']:12.2f} "
              f"{m['tbt_p99']*1e3:12.1f}")

    print(f"\n== latency at 6 req/s fixed interval ==")
    reqs = make_trace(min(args.n, 400), seed=1, interval=1 / 6.0)
    res = compare_all(cfg, A100, A10, reqs)
    for a in APPROACHES:
        m = res[a]
        print(f"{a:12s} ttft_p99={m['ttft_p99']:8.3f}s "
              f"tbt_p99={m['tbt_p99']*1e3:7.1f}ms")

    spec = "2xcronus:A100+A10,2xworker:A10"
    print(f"\n== cluster scale-out: {spec} (6 engines), router comparison ==")
    reqs = make_trace(min(args.n, 600), seed=2, interval=1 / 12.0, sessions=48)
    for router in sorted(ROUTERS):
        system = build_cluster(cfg, spec, router=router)
        m = system.run([copy.deepcopy(r) for r in reqs])
        print(f"{router:12s} tput={m['throughput']:6.2f}req/s "
              f"ttft_p99={m['ttft_p99']:8.3f}s "
              f"tbt_p99={m['tbt_p99']*1e3:7.1f}ms")


if __name__ == "__main__":
    main()
