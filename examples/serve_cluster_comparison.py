"""End-to-end driver on the online serving API: replay an Azure-style
trace against all five serving approaches on a simulated A100+A10 pair
(paper §5 conditions) and print the Table-2/Fig-4 style comparison — then
scale out to a multi-pair cluster and compare the request routers. Every
system is declared as a ``ServeSpec`` and driven through its
``InferenceService``; the trace is re-used safely via ``Trace.fresh()``.

  PYTHONPATH=src python examples/serve_cluster_comparison.py [--n 1000]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.cluster.router import ROUTERS
from repro.serving.api import ServeSpec
from repro.serving.simulator import APPROACHES
from repro.serving.trace import make_trace


def compare(arch, reqs, approaches=APPROACHES):
    out = {}
    for a in approaches:
        service = ServeSpec(arch=arch, approach=a).build()
        out[a] = service.run(reqs.fresh())
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1000)
    ap.add_argument("--arch", default="llama3-8b")
    args = ap.parse_args()

    print(f"== max throughput ({args.n} requests, all at t=0), "
          f"{args.arch} on A100+A10 ==")
    reqs = make_trace(args.n, seed=0, interval=0.0)
    res = compare(args.arch, reqs)
    print(f"{'approach':12s} {'tput(req/s)':>12s} {'ttft_p99(s)':>12s} "
          f"{'tbt_p99(ms)':>12s}")
    for a in APPROACHES:
        m = res[a]
        print(f"{a:12s} {m['throughput']:12.2f} {m['ttft_p99']:12.2f} "
              f"{m['tbt_p99']*1e3:12.1f}")

    print("\n== latency at 6 req/s fixed interval ==")
    reqs = make_trace(min(args.n, 400), seed=1, interval=1 / 6.0)
    res = compare(args.arch, reqs)
    for a in APPROACHES:
        m = res[a]
        print(f"{a:12s} ttft_p99={m['ttft_p99']:8.3f}s "
              f"tbt_p99={m['tbt_p99']*1e3:7.1f}ms")

    cluster = "2xcronus:A100+A10,2xworker:A10"
    print(f"\n== cluster scale-out: {cluster} (6 engines), "
          f"router comparison ==")
    reqs = make_trace(min(args.n, 600), seed=2, interval=1 / 12.0,
                      sessions=48)
    for router in sorted(ROUTERS):
        service = ServeSpec(arch=args.arch, cluster=cluster,
                            router=router).build()
        m = service.run(reqs.fresh())
        print(f"{router:12s} tput={m['throughput']:6.2f}req/s "
              f"ttft_p99={m['ttft_p99']:8.3f}s "
              f"tbt_p99={m['tbt_p99']*1e3:7.1f}ms")


if __name__ == "__main__":
    main()
