"""Train a reduced-config model for a few hundred steps on the synthetic
structured corpus — exercises the full training substrate (AdamW, remat,
data pipeline, checkpointing).

  PYTHONPATH=src python examples/train_small.py --arch mamba2-780m --steps 300
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.models import build_model
from repro.training import AdamWConfig, Trainer, save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt.npz")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    model = build_model(cfg, exact_moe=True)
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps @ bs={args.batch_size} seq={args.seq_len}")
    trainer = Trainer(
        model,
        AdamWConfig(lr=1e-3, warmup_steps=max(args.steps // 10, 1),
                    total_steps=args.steps),
        batch_size=args.batch_size, seq_len=args.seq_len)
    params, opt = trainer.init()
    params, opt, losses = trainer.run(params, opt, args.steps, log_every=25)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")
    save_checkpoint(args.ckpt, params, opt, args.steps)
    print(f"checkpoint saved to {args.ckpt}")


if __name__ == "__main__":
    main()
