"""Quickstart on the online serving API: declare the deployment with a
``ServeSpec``, submit requests to the built ``InferenceService``, stream
one request's tokens as they are generated, and print QoE metrics.

  PYTHONPATH=src python examples/quickstart.py          # real JAX compute
  PYTHONPATH=src python examples/quickstart.py --null   # simulated (CI)
  PYTHONPATH=src python examples/quickstart.py --executor paged
                                        # real compute, block-pool KV
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.configs import get_config
from repro.core.request import Request
from repro.serving.api import ServeSpec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--null", action="store_true",
                    help="NullExecutor (no tensor compute; CI smoke)")
    ap.add_argument("--executor", default=None,
                    choices=("null", "real", "paged"),
                    help="compute backend (overrides --null; 'paged' = "
                         "real compute over block-pool KV)")
    args = ap.parse_args()

    # 1. the whole deployment as one declarative spec: a reduced
    #    llama3-8b-family model on an A100 (CPI) + A10 (PPI) Cronus pair,
    #    real JAX execution unless --null
    executor = args.executor or ("null" if args.null else "real")
    spec = ServeSpec(arch="llama3-8b", smoke=True,
                     approach="cronus", hi="A100", lo="A10",
                     executor=executor,
                     max_slots=4, block_size=8, max_batched_tokens=32,
                     s_kv=256, chunk_pad=32)
    cfg = get_config(spec.arch, smoke=spec.smoke)
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f} M params)")

    # 2. build it: balancer, engines, executors and router are assembled
    #    from the spec (no kwarg threading through the core builders)
    service = spec.build()

    # 3. submit a few requests — each returns a live handle
    rng = np.random.default_rng(0)
    handles = [service.submit(
        Request(req_id=f"req{i}",
                prompt=rng.integers(0, cfg.vocab_size, n).astype(np.int32),
                output_len=8))
        for i, n in enumerate((24, 57, 91))]

    # 4. stream the last request's tokens as they arrive (this advances
    #    the whole cluster's simulated time; the other requests progress
    #    concurrently)
    for tok, t in handles[-1].tokens():
        print(f"  {handles[-1].req_id} @ t={t:7.4f}s -> token {tok}")

    # 5. drain the rest and report
    metrics = service.drain()
    for h in sorted(handles, key=lambda h: h.req_id):
        r = h.request
        print(f"{r.req_id}: L_in={r.input_len} partial_len={r.partial_len} "
              f"(PPI did {100*r.partial_len/r.input_len:.0f}%) "
              f"tokens={r.generated}")
    print({k: round(v, 4) for k, v in metrics.items()})


if __name__ == "__main__":
    main()
