"""Quickstart: serve a reduced-config model through Cronus (real JAX
execution) and print the generated tokens + QoE metrics.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_config
from repro.core.balancer import Balancer
from repro.core.cronus import build_cronus
from repro.core.executor import RealExecutor
from repro.core.predictor import profile_chunked, profile_prefill
from repro.core.request import Request
from repro.models import build_model
from repro.serving.hardware import A10, A100, DeviceModel


def main():
    # 1. a reduced llama3-8b-family model (full configs are dry-run only)
    cfg = get_config("llama3-8b", smoke=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f} M params)")

    # 2. the heterogeneous pair: A100 (CPI) + A10 (PPI), roofline-timed
    hi, lo = DeviceModel(A100, cfg), DeviceModel(A10, cfg)

    # 3. Balancer = Algorithm 1 over profiled linear predictors (Eq. 2-3)
    balancer = Balancer(profile_prefill(lo), profile_chunked(hi))

    # 4. the Cronus system: PPI + KV buffer + CPI with chunked prefill
    system = build_cronus(
        cfg, lo, hi,
        executor_factory=lambda role: RealExecutor(
            model, params, max_slots=4, s_kv=256, chunk_pad=32),
        balancer=balancer, max_batched_tokens=32, max_slots=4, block_size=8)

    # 5. a few requests
    rng = np.random.default_rng(0)
    reqs = [Request(req_id=f"req{i}",
                    prompt=rng.integers(0, cfg.vocab_size, n).astype(np.int32),
                    output_len=8)
            for i, n in enumerate((24, 57, 91))]
    metrics = system.run(reqs)

    for r in sorted(system.cpi.finished, key=lambda r: r.req_id):
        print(f"{r.req_id}: L_in={r.input_len} partial_len={r.partial_len} "
              f"(PPI did {100*r.partial_len/r.input_len:.0f}%) "
              f"tokens={r.generated}")
    print({k: round(v, 4) for k, v in metrics.items()})


if __name__ == "__main__":
    main()
