"""Benchmark harness — one entry per paper table/figure (+ roofline,
balancer ablation, kernel numerics). Prints ``name,us_per_call,derived``
CSV rows. Run: ``PYTHONPATH=src python -m benchmarks.run [--quick]``."""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller request counts (CI mode)")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    args, _ = ap.parse_known_args()

    from benchmarks import (bench_balancer_ablation, bench_cluster_scaling,
                            bench_fig3_predictor_fit, bench_fig4_latency,
                            bench_kernels, bench_offload_limitation,
                            bench_roofline, bench_scheduler_ablation,
                            bench_table2_throughput,
                            bench_table3_utilization)

    n2 = 250 if args.quick else 600
    n4 = 200 if args.quick else 400
    benches = {
        "table2": lambda: bench_table2_throughput.run(n_requests=n2),
        "fig3": bench_fig3_predictor_fit.run,
        "fig4": lambda: bench_fig4_latency.run(n_requests=n4),
        "table3": lambda: bench_table3_utilization.run(n_requests=n4),
        "balancer_ablation": lambda: bench_balancer_ablation.run(
            n_requests=n4),
        "offload_limitation": lambda: bench_offload_limitation.run(
            n_requests=n4),
        "cluster_scaling": lambda: bench_cluster_scaling.run(
            n_requests=150 if args.quick else 300),
        "scheduler_ablation": lambda: bench_scheduler_ablation.run(
            n_requests=80 if args.quick else 300,
            out_path="BENCH_scheduler_ablation.json"),
        "kernels": bench_kernels.run,
        "roofline": bench_roofline.run,
    }
    only = set(args.only.split(",")) if args.only else None
    failures = 0
    for name, fn in benches.items():
        if only and name not in only:
            continue
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            fn()
        except Exception:
            failures += 1
            print(f"{name}/ERROR,0,{traceback.format_exc(limit=2)!r}")
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
