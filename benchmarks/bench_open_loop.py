"""Open-loop load sweep: latency-vs-rate curves + SLO capacity search
(the paper's Fig.-4 experiment shape, run honestly).

Every other benchmark replays a pre-sorted trace closed-loop; this one
drives each system through :class:`~repro.workloads.OpenLoopDriver` —
live submission at the arrival process's wall-time offsets — so TTFT
tails include real queueing. Two traffic models per system:

  * ``poisson:RATE`` — the paper's rate-swept setting;
  * ``burst:RATE`` — Markov-modulated on/off at 4x the mean rate, the
    regime where schedulers that look fine on smooth arrivals fall over.

For each system x model the sweep reports TTFT/TBT percentiles, the
queueing/service split, and goodput at the default SLOs per rate; a
bisection then finds the *SLO-sustainable capacity* — the largest rate
whose goodput stays >= the target — which is the single number the
curves are usually read for.

Row keys for the regression gate: ``rig`` (system) + ``trace``
(``{model}@{rate}qps`` for curve points, ``{model}_capacity`` for the
search result, whose capacity doubles as the gated ``throughput``
column).

Run: ``PYTHONPATH=src python -m benchmarks.bench_open_loop [--quick]
[--out BENCH_open_loop.json]``
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List

from benchmarks.common import DEFAULT_TBT_SLO, DEFAULT_TTFT_SLO
from repro.serving.api import ServeSpec
from repro.serving.trace import make_trace
from repro.workloads import find_capacity, open_loop_measure

SYSTEMS = ("cronus", "dp", "pp")
ARRIVALS = {
    # !r keeps bisection-probed rates exact (e.g. 4.921875), so the process
    # runs at precisely the rate the row reports
    "poisson": "poisson:{rate!r}",
    # 4x peak-to-mean, 5 s mean ON phases: a few dozen requests per burst
    "burst": "burst:{rate!r}:4:5",
}
SLO_TARGET = 0.9          # capacity = max rate with goodput >= this

CURVE_KEYS = ("throughput", "ttft_p50", "ttft_p99", "tbt_p99",
              "queueing_p99", "ttft_service_p99", "goodput", "completed")


def _factories(approach: str, model: str, n: int, seed: int):
    def make_service():
        return ServeSpec(approach=approach).build()

    def make_requests(rate: float):
        return make_trace(n, seed=seed,
                          arrival=ARRIVALS[model].format(rate=rate))
    return make_service, make_requests


def run(n_requests: int, rates: List[float], cap_lo: float, cap_hi: float,
        cap_iters: int, seed: int = 0, out_path: str = None) -> List[Dict]:
    rows: List[Dict] = []
    for model in ARRIVALS:
        for approach in SYSTEMS:
            make_service, make_requests = _factories(
                approach, model, n_requests, seed)
            for rate in rates:
                m = open_loop_measure(make_service, make_requests, rate,
                                      ttft_slo=DEFAULT_TTFT_SLO,
                                      tbt_slo=DEFAULT_TBT_SLO)
                row = {"rig": approach, "trace": f"{model}@{rate:g}qps",
                       "rate": rate, "ttft_slo": DEFAULT_TTFT_SLO,
                       "tbt_slo": DEFAULT_TBT_SLO,
                       **{k: m[k] for k in CURVE_KEYS}}
                rows.append(row)
                print(f"open_loop/{approach}/{model}@{rate:g}qps,0,"
                      f"ttft_p99={m['ttft_p99']:.3f} "
                      f"queue_p99={m['queueing_p99']:.3f} "
                      f"tbt_p99={m['tbt_p99']:.4f} "
                      f"goodput={m['goodput']:.3f}")
            cap = find_capacity(make_service, make_requests, cap_lo, cap_hi,
                                target=SLO_TARGET, ttft_slo=DEFAULT_TTFT_SLO,
                                tbt_slo=DEFAULT_TBT_SLO, rel_tol=0.08,
                                max_iters=cap_iters)
            rows.append({"rig": approach, "trace": f"{model}_capacity",
                         "slo_target": SLO_TARGET,
                         # capacity is a sustainable request rate, so it
                         # doubles as the regression gate's throughput column
                         "throughput": cap.rate, "capacity_qps": cap.rate,
                         "n_probes": len(cap.evaluations)})
            print(f"open_loop/{approach}/{model}_capacity,0,"
                  f"capacity={cap.rate:.2f}qps "
                  f"probes={len(cap.evaluations)}")
    _summary(rows)
    if out_path:
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"# wrote {out_path}")
    return rows


def _summary(rows: List[Dict]):
    print("\n# SLO-sustainable capacity (goodput >= "
          f"{SLO_TARGET:.0%} at TTFT<={DEFAULT_TTFT_SLO}s, "
          f"TBT<={DEFAULT_TBT_SLO}s):")
    for model in ARRIVALS:
        caps = {r["rig"]: r["capacity_qps"] for r in rows
                if r["trace"] == f"{model}_capacity"}
        ranked = sorted(caps, key=caps.get, reverse=True)
        line = "  ".join(f"{s}={caps[s]:.2f}" for s in ranked)
        print(f"#   {model:8s} {line}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller request counts / rate grid (CI smoke)")
    ap.add_argument("--n-requests", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="write rows as JSON (e.g. BENCH_open_loop.json)")
    args = ap.parse_args()
    n = args.n_requests or (100 if args.quick else 300)
    rates = [2.0, 6.0] if args.quick else [2.0, 5.0, 8.0]
    cap_iters = 4 if args.quick else 6
    # hi bracket well past every system's closed-loop throughput (~7-8
    # req/s): short traces only violate the 5 s TTFT SLO once the backlog
    # outgrows the run, so the search needs room above the knee
    run(n_requests=n, rates=rates, cap_lo=1.0, cap_hi=24.0,
        cap_iters=cap_iters, seed=args.seed, out_path=args.out)


if __name__ == "__main__":
    main()
