"""Roofline table from the dry-run artifacts (results/dryrun/*.json):
per (arch x shape x mesh) compute/memory/collective terms + bottleneck.
Falls back to a reduced in-line summary when artifacts are absent."""
from __future__ import annotations

import glob
import json
import os


def _load(results_dir):
    out = {}
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        if path.endswith("summary.json"):
            continue
        with open(path) as f:
            r = json.load(f)
        out[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r
    return out


def run(results_dir: str = "results/dryrun",
        baseline_dir: str = "results/dryrun_baseline"):
    print("name,us_per_call,derived")
    cur = _load(results_dir)
    base = _load(baseline_dir) if os.path.isdir(baseline_dir) else {}
    if not cur:
        print("roofline/missing,0,run `python -m repro.launch.sweep` first")
        return
    n_ok = 0
    for key, r in sorted(cur.items()):
        tag = "/".join(str(k) for k in key)
        if r.get("status") != "ok":
            print(f"roofline/{tag},0,FAILED {r.get('error')}")
            continue
        n_ok += 1
        extra = ""
        b = base.get(key)
        if b and b.get("status") == "ok":
            tot_b = b["t_compute"] + b["t_memory"] + b["t_collective"]
            tot_c = r["t_compute"] + r["t_memory"] + r["t_collective"]
            if tot_c > 0:
                extra = f" vs_baseline={tot_b/tot_c:.2f}x"
        print(f"roofline/{tag},{r.get('t_compile_s', 0)*1e6:.0f},"
              f"compute={r['t_compute']*1e3:.3f}ms "
              f"memory={r['t_memory']*1e3:.3f}ms "
              f"collective={r['t_collective']*1e3:.3f}ms "
              f"bound={r['bottleneck']} "
              f"useful_flops={r.get('useful_flops_ratio', float('nan')):.3f}"
              f"{extra}")
    print(f"roofline/summary,0,{n_ok}/{len(cur)} ok")


if __name__ == "__main__":
    run()
