"""Paged vs slot real executor: why block-pool KV is the right layout.

Two rigs, one claim each:

``decode_ctx`` — decode step cost vs *provisioned* capacity. The slot
executor's dense per-slot buffer makes every decode step attend over the
full padded ``s_kv`` width regardless of how short the actual context is;
the paged executor attends over ``bucket(ceil(ctx / page))`` live pages,
so its cost is flat as provisioning grows. Attention widths are
deterministic and self-gated here (paged flat, slot == s_kv); wall-clock
ms per step is reported as machine-local evidence, not gated.

``paged_serve`` — REAL prefix-cache hits. The same shared-prefix trace is
served twice on real compute by the paged executor, cold and with
``@cache``; the cached run must reuse prefix blocks (tokens_reused > 0 —
prefill work actually skipped, which the slot executor cannot do at all)
while producing token-identical outputs. Simulated throughput / TTFT-P99
(deterministic roofline clocks) feed the regression gate.

Run: ``PYTHONPATH=src python -m benchmarks.bench_paged_executor
[--quick] [--out BENCH_paged_executor.json]``
"""
from __future__ import annotations

import argparse
import json
import time
import types
from typing import Dict, List

import numpy as np

ARCH = "llama3-8b"
BLOCK = 4                  # KV page size (tokens) for both rigs
B = 4                      # decode batch (resident requests)
ACT = 48                   # actual per-request context at the first step
STEPS = 16                 # timed decode steps per measurement


# ---------------------------------------------------------------------------
# rig 1: decode step cost vs provisioned capacity
# ---------------------------------------------------------------------------

class _StubEngine:
    """Minimal engine surface for driving a PagedRealExecutor directly."""

    def __init__(self, num_kv_blocks: int, max_slots: int):
        from repro.core.engine import EngineConfig
        from repro.kvcache.allocator import BlockAllocator
        self.ecfg = EngineConfig(max_slots=max_slots, block_size=BLOCK,
                                 num_kv_blocks=num_kv_blocks,
                                 executor="paged")
        self.allocator = BlockAllocator(num_kv_blocks, BLOCK)
        self.slots = [None] * max_slots


def _median_step(step_fn, warmup: int = 3, iters: int = 7) -> float:
    for _ in range(warmup):
        step_fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        step_fn()
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def _prefill_all(ex, prompts, chunk: int = 16):
    last = {}
    for s, p in enumerate(prompts):
        for lo in range(0, len(p), chunk):
            hi = min(lo + chunk, len(p))
            last[s] = ex.prefill_chunk(s, p[lo:hi], lo, hi == len(p))
    return last


def _decode_stepper(ex, last):
    state = {"toks": dict(last), "pos": {s: ACT for s in last}}

    def step():
        out = ex.decode(state["toks"], state["pos"])
        state["toks"] = out
        state["pos"] = {s: p + 1 for s, p in state["pos"].items()}
    return step


def _rig_decode_ctx(model, params, sweep: List[int]) -> List[Dict]:
    from repro.core.executor import PagedRealExecutor, RealExecutor
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, model.cfg.vocab_size, ACT).astype(np.int32)
               for _ in range(B)]
    rows = []

    # paged cost depends only on actual context: measure once, reuse.
    px = PagedRealExecutor(model, params)
    eng = _StubEngine(num_kv_blocks=B * ((ACT + STEPS * 3) // BLOCK + 2),
                      max_slots=B)
    px.attach_engine(eng)
    for s in range(B):
        eng.allocator.allocate(f"r{s}", ACT + STEPS * 3 + 2)
        eng.slots[s] = types.SimpleNamespace(req_id=f"r{s}")
    paged_ms = 1e3 * _median_step(_decode_stepper(
        px, _prefill_all(px, prompts)))
    paged_width = px.buckets.bucket(-(-(ACT + 1) // BLOCK), lo=4) * BLOCK

    for s_kv in sweep:
        ex = RealExecutor(model, params, max_slots=B, s_kv=s_kv,
                          chunk_pad=16)
        slot_ms = 1e3 * _median_step(_decode_stepper(
            ex, _prefill_all(ex, prompts)))
        rows.append({"rig": "decode_ctx", "trace": f"skv{s_kv}",
                     "slot_attn_width": s_kv,
                     "paged_attn_width": paged_width,
                     "slot_ms_per_step": round(slot_ms, 3),
                     "paged_ms_per_step": round(paged_ms, 3)})
        print(f"paged_executor/decode_ctx/skv{s_kv},0,"
              f"slot={slot_ms:.2f}ms paged={paged_ms:.2f}ms "
              f"width {s_kv} vs {paged_width}")

    # the layout claim is deterministic: slot attention width tracks
    # provisioning, paged width tracks actual context only
    widths = [r["paged_attn_width"] for r in rows]
    assert len(set(widths)) == 1, widths
    slot_w = [r["slot_attn_width"] for r in rows]
    assert slot_w == sorted(slot_w) and len(set(slot_w)) == len(slot_w)
    return rows


# ---------------------------------------------------------------------------
# rig 2: real prefix-cache hits under serving
# ---------------------------------------------------------------------------

def _shared_prefix_reqs(vocab: int, n: int):
    from repro.core.request import Request
    rng = np.random.default_rng(11)
    # 4 tenant templates, misaligned length (26 % 4 != 0) so divergence
    # exercises the CoW copy, short suffixes/outputs (CPU-scale)
    prefixes = [rng.integers(0, vocab, 26).astype(np.int32)
                for _ in range(4)]
    reqs = []
    for i in range(n):
        pre = prefixes[i % len(prefixes)]
        tail = rng.integers(0, vocab, int(rng.integers(4, 12)))
        reqs.append(Request(
            req_id=f"q{i}",
            prompt=np.concatenate([pre, tail.astype(np.int32)]),
            output_len=4, arrival=0.25 * i))
    return reqs


def _rig_paged_serve(model, params, n: int) -> List[Dict]:
    from repro.serving.api import ServeSpec
    rows = []
    streams = {}
    for cache in (False, True):
        spec = ServeSpec(
            cluster="worker:A100" + ("@cache" if cache else ""),
            smoke=True, executor="paged", s_kv=64, max_slots=4,
            block_size=BLOCK, max_batched_tokens=16)
        svc = spec.build(model=model, params=params)
        reqs = _shared_prefix_reqs(model.cfg.vocab_size, n)
        t0 = time.perf_counter()
        m = svc.run(reqs)
        wall = time.perf_counter() - t0
        eng = svc.engines[0]
        streams[cache] = {r.req_id: list(r.generated) for r in eng.finished}
        reused = eng.allocator.n_tokens_reused
        row = {"rig": "paged_serve", "trace": "shared_prefix",
               "cache": cache, "throughput": m["throughput"],
               "ttft_p99": m["ttft_p99"], "tokens_reused": reused,
               "cow_copies": eng.allocator.n_cow_copies,
               "compile_shapes": eng.executor.compile_stats()[
                   "total_shapes"],
               "wall_s": round(wall, 2)}
        rows.append(row)
        print(f"paged_executor/paged_serve/cache={int(cache)},0,"
              f"tput={m['throughput']:.3f} ttft_p99={m['ttft_p99']:.4f} "
              f"reused={reused} wall={wall:.2f}s")
    assert streams[True] == streams[False], \
        "prefix cache changed tokens on real compute"
    assert rows[1]["tokens_reused"] > 0, "no real cache hits"
    assert rows[0]["tokens_reused"] == 0
    return rows


# ---------------------------------------------------------------------------

def run(quick: bool = False, out_path: str = None) -> List[Dict]:
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config(ARCH, smoke=True)
    model = build_model(cfg, exact_moe=True)
    params = model.init_params(jax.random.PRNGKey(0))

    sweep = [128, 512] if quick else [128, 256, 512, 1024]
    rows = _rig_decode_ctx(model, params, sweep)
    rows += _rig_paged_serve(model, params, n=12 if quick else 24)

    if out_path:
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"# wrote {out_path}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweep (CI smoke / regression gate)")
    ap.add_argument("--out", default=None,
                    help="write rows as JSON (BENCH_paged_executor.json)")
    args = ap.parse_args()
    run(quick=args.quick, out_path=args.out)


if __name__ == "__main__":
    main()
