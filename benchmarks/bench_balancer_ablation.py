"""Balancer ablation (§4.3): Algorithm 1's predictive split vs fixed-ratio
splits (25/50/75%) and the degenerate full-split (== disaggregated L-H).
Shows the adaptive split is what buys Cronus its throughput."""
from __future__ import annotations

import time

from benchmarks.common import paper_trace
from repro.configs import get_config
from repro.core.balancer import Balancer
from repro.core.cronus import build_cronus
from repro.core.executor import NullExecutor
from repro.core.predictor import profile_chunked, profile_prefill
from repro.serving.hardware import A10, A100, DeviceModel


class RatioBalancer:
    def __init__(self, ratio: float):
        self.ratio = ratio

    def partial_prefill_length(self, l_in, stats):
        return max(1, min(int(l_in * self.ratio), l_in))


def run(n_requests: int = 500):
    print("name,us_per_call,derived")
    cfg = get_config("llama3-8b")
    hi, lo = DeviceModel(A100, cfg), DeviceModel(A10, cfg)
    reqs = paper_trace(n_requests)
    variants = {
        "alg1": Balancer(profile_prefill(lo), profile_chunked(hi)),
        "fixed_25": RatioBalancer(0.25),
        "fixed_50": RatioBalancer(0.50),
        "fixed_75": RatioBalancer(0.75),
        "full_split": RatioBalancer(1.0),     # == disaggregated L-H
    }
    for name, bal in variants.items():
        t0 = time.time()
        sys_c = build_cronus(cfg, lo, hi,
                             executor_factory=lambda role: NullExecutor(),
                             balancer=bal)
        m = sys_c.run(reqs.fresh())
        wall = (time.time() - t0) * 1e6 / n_requests
        print(f"balancer_ablation/{name},{wall:.1f},"
              f"tput={m['throughput']:.2f}req/s "
              f"ttft_p99={m['ttft_p99']:.2f}s")


if __name__ == "__main__":
    run()
