"""Paper Fig. 3 + §4.4: quality of the linear execution-time predictors.

Paper reference: prefill Eq. 2 on A30 — R2 0.993, MAPE 7.4%;
chunked-iteration Eq. 3 on A100 (Fig. 3) — R2 0.990, MAPE 0.8%.
Ours are fitted on roofline-model profiles of the same devices, plus a
measured-wall-time fit of the REAL engine on CPU (methodology identical to
the paper's: profile, then least-squares)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.executor import RealExecutor
from repro.core.predictor import (PrefillPredictor, profile_chunked,
                                  profile_prefill)
from repro.models import build_model
from repro.serving.hardware import A30, A100, DeviceModel


def run():
    print("name,us_per_call,derived")
    cfg = get_config("llama3-8b")

    t0 = time.time()
    pre = profile_prefill(DeviceModel(A30, cfg))
    print(f"fig3/eq2_prefill_A30,{(time.time()-t0)*1e6:.1f},"
          f"r2={pre.r2:.4f} mape={pre.mape*100:.1f}% paper_r2=0.993 "
          f"paper_mape=7.4%")

    t0 = time.time()
    chk = profile_chunked(DeviceModel(A100, cfg))
    print(f"fig3/eq3_chunked_A100,{(time.time()-t0)*1e6:.1f},"
          f"r2={chk.r2:.4f} mape={chk.mape*100:.1f}% paper_r2=0.990 "
          f"paper_mape=0.8%")

    # measured wall-time fit on the real CPU engine (reduced config)
    scfg = get_config("llama3-8b", smoke=True)
    model = build_model(scfg)
    params = model.init_params(jax.random.PRNGKey(0))
    lengths = [16, 32, 64, 96, 128, 192, 256]
    times = []
    ex = RealExecutor(model, params, max_slots=1, s_kv=512)
    for n in lengths:  # warm up each shape, then time
        toks = np.arange(n) % scfg.vocab_size
        ex.reset_slot(0)
        ex.prefill_chunk(0, toks, 0, True)
        ex.reset_slot(0)
        t0 = time.time()
        ex.prefill_chunk(0, toks, 0, True)
        times.append(time.time() - t0)
    fit = PrefillPredictor().fit(lengths, times)
    print(f"fig3/eq2_measured_cpu,{np.mean(times)*1e6:.1f},"
          f"r2={fit.r2:.4f} mape={fit.mape*100:.1f}% "
          f"k_p={fit.k_p*1e3:.4f}ms/tok")


if __name__ == "__main__":
    run()
