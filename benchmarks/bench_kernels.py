"""Kernel microbenchmarks: Pallas (interpret) vs jnp reference wall times on
CPU are NOT performance numbers (TPU is the target); this bench validates
numerics at larger shapes and reports the ref path's CPU throughput as a
regression canary."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.chunked_prefill_attention import chunked_prefill_attention_pallas
from repro.kernels.paged_attention import paged_decode_attention_pallas


def run():
    print("name,us_per_call,derived")
    key = jax.random.PRNGKey(0)

    b, c, h, kv, d, s = 2, 128, 8, 2, 128, 1024
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, c, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, d), jnp.float32)
    ctx = jnp.array([512, 700])
    q_pos = (ctx[:, None] + jnp.arange(c)[None, :]).astype(jnp.int32)
    kv_pos = jnp.where(jnp.arange(s)[None, :] < (ctx + c)[:, None],
                       jnp.arange(s)[None, :], -1).astype(jnp.int32)

    fn = jax.jit(lambda *a: ref.chunked_prefill_attention_ref(*a, 0))
    fn(q, k, v, q_pos, kv_pos).block_until_ready()
    t0 = time.time()
    for _ in range(5):
        out_ref = fn(q, k, v, q_pos, kv_pos).block_until_ready()
    t_ref = (time.time() - t0) / 5
    print(f"kernels/chunked_prefill_ref_xla,{t_ref*1e6:.1f},"
          f"shape=b{b}c{c}h{h}d{d}s{s}")

    out_pl = chunked_prefill_attention_pallas(q, k, v, q_pos, kv_pos,
                                              block_q=128, block_k=128,
                                              interpret=True)
    err = float(jnp.max(jnp.abs(out_pl - out_ref)))
    print(f"kernels/chunked_prefill_pallas_interp,0,max_err={err:.2e}")

    p_tot, page, maxp = 64, 16, 16
    q2 = jax.random.normal(ks[0], (8, h, d), jnp.float32)
    kp = jax.random.normal(ks[1], (p_tot, page, kv, d), jnp.float32)
    vp = jax.random.normal(ks[2], (p_tot, page, kv, d), jnp.float32)
    bt = jax.random.randint(key, (8, maxp), 0, p_tot)
    cl = (jnp.arange(8) * 29 % (maxp * page - 1) + 1).astype(jnp.int32)
    fn2 = jax.jit(ref.paged_decode_attention_ref)
    fn2(q2, kp, vp, bt, cl).block_until_ready()
    t0 = time.time()
    for _ in range(5):
        out2_ref = fn2(q2, kp, vp, bt, cl).block_until_ready()
    t2 = (time.time() - t0) / 5
    print(f"kernels/paged_decode_ref_xla,{t2*1e6:.1f},pages={p_tot}x{page}")
    out2 = paged_decode_attention_pallas(q2, kp, vp, bt, cl, interpret=True)
    err2 = float(jnp.max(jnp.abs(out2 - out2_ref)))
    print(f"kernels/paged_decode_pallas_interp,0,max_err={err2:.2e}")


if __name__ == "__main__":
    run()
