"""Paper §6 (Limitations): with short-input/long-output traces the CPI
becomes decode-bound and Cronus load-balance breaks. The paper leaves the
fix ("offloading some decode requests to the prefill node") as future
work — we implement it (CronusSystem.decode_offload) and measure it here.

FINDINGS (EXPERIMENTS.md §Perf-offload):
  * unbounded offload (trigger = Alg. 1 fallback alone) inverts the system
    into Disagg-H-L: 3.4 -> 0.17 req/s. REFUTED; policy now bounds offload
    by the PPI's spare KV pool (max_offload_frac).
  * bounded offload on A100+A10 is throughput-neutral-to-negative
    (3.92 -> 3.85 req/s at CPI-saturating load): with a 4-5x decode-speed
    gap the offloaded stragglers on the A10 set the tail. The paper's idea
    pays only when the capability gap is small or the high-end side is
    memory- (not bandwidth-) limited. Feature ships default-off."""
from __future__ import annotations

import time

from repro.configs import get_config
from repro.core.balancer import Balancer
from repro.core.cronus import build_cronus
from repro.core.executor import NullExecutor
from repro.core.predictor import profile_chunked, profile_prefill
from repro.serving.hardware import A10, A100, DeviceModel
from repro.serving.trace import make_trace


def run(n_requests: int = 400):
    print("name,us_per_call,derived")
    cfg = get_config("llama3-8b")
    hi, lo = DeviceModel(A100, cfg), DeviceModel(A10, cfg)
    # decode-bound trace: short inputs, long outputs (inverts the paper's
    # conversation statistics)
    reqs = make_trace(n_requests, seed=2, interval=0.0,
                      mean_in=150, mean_out=900, max_out=2048)
    for name, offload in (("cronus", False), ("cronus+offload", True)):
        bal = Balancer(profile_prefill(lo), profile_chunked(hi))
        t0 = time.time()
        sys_c = build_cronus(cfg, lo, hi,
                             executor_factory=lambda role: NullExecutor(),
                             balancer=bal, decode_offload=offload)
        m = sys_c.run(reqs.fresh())
        wall = (time.time() - t0) * 1e6 / n_requests
        n_ppi = len(sys_c.ppi.finished)
        print(f"offload/{name},{wall:.1f},tput={m['throughput']:.2f}req/s "
              f"tbt_p99={m['tbt_p99']*1000:.1f}ms "
              f"finished_on_ppi={n_ppi}")


if __name__ == "__main__":
    run()
