"""Shared benchmark plumbing."""
from __future__ import annotations

import time
from typing import Callable

from repro.core.metrics import slo_attainment
from repro.serving.trace import make_trace
from repro.workloads import sweep as _sweep

# Latency deadlines for goodput (SLO-attainment) reporting — canonical
# values live in repro.workloads.sweep (the capacity search targets them);
# re-exported here so every benchmark keeps importing them from one place.
# Scheduler ablations report goodput alongside raw throughput so a policy
# can't win by starving the tail.
DEFAULT_TTFT_SLO = _sweep.DEFAULT_TTFT_SLO    # seconds
DEFAULT_TBT_SLO = _sweep.DEFAULT_TBT_SLO      # seconds/token

# the paper's evaluation grid (Table 2 / Fig. 4 columns)
PAPER_GRID = [
    ("A100", "A10", "llama3-8b"),
    ("A100", "A10", "qwen2-7b"),
    ("A100", "A30", "llama3-8b"),
    ("A100", "A30", "qwen2-7b"),
]

# paper Table 2 reference numbers (req/s) for side-by-side reporting
PAPER_TABLE2 = {
    ("A100", "A10", "llama3-8b"): {"dp": 7.28, "pp": 3.86, "disagg_hl": 1.31,
                                   "disagg_lh": 4.11, "cronus": 7.39},
    ("A100", "A10", "qwen2-7b"): {"dp": 8.70, "pp": 4.08, "disagg_hl": 3.45,
                                  "disagg_lh": 4.35, "cronus": 8.29},
    ("A100", "A30", "llama3-8b"): {"dp": 8.54, "pp": 3.96, "disagg_hl": 2.93,
                                   "disagg_lh": 6.14, "cronus": 8.7},
    ("A100", "A30", "qwen2-7b"): {"dp": 10.85, "pp": 3.97, "disagg_hl": 6.74,
                                  "disagg_lh": 6.59, "cronus": 10.27},
}


def paper_trace(n: int = 1000, interval: float = 0.0, seed: int = 0):
    """Azure-conversation-statistics trace (paper §5.1: 1000 traces,
    mean in 1014 / out 247)."""
    return make_trace(n, seed=seed, interval=interval)


def timed(name: str, fn: Callable):
    t0 = time.time()
    out = fn()
    return out, time.time() - t0


def emit_csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.3f},{derived}")


def goodput(requests, ttft_slo: float = DEFAULT_TTFT_SLO,
            tbt_slo: float = DEFAULT_TBT_SLO) -> float:
    """SLO attainment over a replayed trace: pass the ORIGINAL request list
    (its metrics objects are shared with the engines), so requests the
    system never finished count as misses."""
    return slo_attainment([r.metrics for r in requests], ttft_slo, tbt_slo)
