"""Paper Table 2: max throughput (req/s) of 5 approaches x 4 (hw, model)
combos. All requests sent at t=0 (the paper's measurement mode)."""
from __future__ import annotations

import time

from benchmarks.common import PAPER_GRID, PAPER_TABLE2, paper_trace
from repro.configs import get_config
from repro.serving.hardware import DEVICES
from repro.serving.simulator import APPROACHES, run_approach


def run(n_requests: int = 600):
    print("name,us_per_call,derived")
    rows = {}
    for hi, lo, arch in PAPER_GRID:
        cfg = get_config(arch)
        reqs = paper_trace(n_requests)
        for approach in APPROACHES:
            t0 = time.time()
            m = run_approach(approach, cfg, DEVICES[hi], DEVICES[lo], reqs)
            wall = (time.time() - t0) * 1e6 / max(n_requests, 1)
            paper = PAPER_TABLE2[(hi, lo, arch)][approach]
            rows[(hi, lo, arch, approach)] = m["throughput"]
            print(f"table2/{hi}+{lo}/{arch}/{approach},{wall:.1f},"
                  f"tput={m['throughput']:.2f}req/s paper={paper}")
    # headline ratios the paper reports
    for (hi, lo, arch) in [g for g in PAPER_GRID]:
        c = rows[(hi, lo, arch, "cronus")]
        print(f"table2_ratio/{hi}+{lo}/{arch},0,"
              f"vsPP={c/rows[(hi, lo, arch, 'pp')]:.2f}x "
              f"vsHL={c/rows[(hi, lo, arch, 'disagg_hl')]:.2f}x "
              f"vsLH={c/rows[(hi, lo, arch, 'disagg_lh')]:.2f}x "
              f"vsDP={c/rows[(hi, lo, arch, 'dp')]:.2f}x")
    return rows


if __name__ == "__main__":
    run()
