"""Paper Table 3 (Appendix B): relative GPU utilization under disaggregated
prefill — the dedicated low-end instance saturates (~100%) while the
high-end one idles (11-54% in the paper)."""
from __future__ import annotations

import time

from benchmarks.common import PAPER_GRID, paper_trace
from repro.configs import get_config
from repro.serving.hardware import DEVICES
from repro.serving.simulator import utilization_table

PAPER_TABLE3 = {  # (combo, approach) -> (prefill_util, decode_util)
    ("A100", "A10", "llama3-8b", "disagg_hl"): (0.11, 0.97),
    ("A100", "A10", "llama3-8b", "disagg_lh"): (0.99, 0.32),
    ("A100", "A30", "llama3-8b", "disagg_hl"): (0.25, 0.96),
    ("A100", "A30", "llama3-8b", "disagg_lh"): (0.98, 0.47),
}


def run(n_requests: int = 400):
    print("name,us_per_call,derived")
    for hi, lo, arch in PAPER_GRID:
        if arch != "llama3-8b":
            continue
        cfg = get_config(arch)
        reqs = paper_trace(n_requests)
        t0 = time.time()
        table = utilization_table(cfg, DEVICES[hi], DEVICES[lo], reqs)
        wall = (time.time() - t0) * 1e6 / n_requests
        for name, row in table.items():
            paper = PAPER_TABLE3.get((hi, lo, arch, name))
            print(f"table3/{hi}+{lo}/{arch}/{name},{wall:.1f},"
                  f"prefill_util={row['prefill_util']:.2f} "
                  f"decode_util={row['decode_util']:.2f} "
                  f"paper={paper}")


if __name__ == "__main__":
    run()
