"""Scheduler-policy ablation: policies x traces on throughput / TTFT P99 /
TBT P99 / goodput / preemptions.

Two rigs:
  * ``worker`` — one A10 chunked-prefill+decode instance with its natural
    HBM-derived KV pool. This isolates the batch-composition policy from
    routing/balancing: fcfs reserves ``input+output`` blocks per request at
    admission (the seed behaviour), so in decode-bound regimes its resident
    batch is starved; sarathi/sjf admit on prompt-only reservations, grow
    paged KV lazily and preempt-by-recompute on OOM.
  * ``cronus`` — the full A100+A10 Balancer pair, showing how the policy
    interacts with Algorithm 1 (whose admission gate reads the free-block
    signal that lazy growth makes honest).

Run: ``PYTHONPATH=src python -m benchmarks.bench_scheduler_ablation
[--quick] [--out BENCH_scheduler_ablation.json]``
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List

from benchmarks.common import DEFAULT_TBT_SLO, DEFAULT_TTFT_SLO, goodput
from repro.cluster.router import RoundRobinRouter
from repro.cluster.runtime import ClusterRuntime, WorkerEndpoint
from repro.configs import get_config
from repro.core.engine import Engine, EngineConfig
from repro.core.executor import NullExecutor
from repro.serving.hardware import A10, A100, DeviceModel
from repro.serving.simulator import build_system
from repro.serving.trace import make_trace

POLICIES = ("fcfs", "sarathi", "sjf")


def _traces(n: int) -> Dict[str, List]:
    return {
        # the paper's Azure-conversation shape, max-throughput mode
        "azure_maxtput": make_trace(n, seed=0, interval=0.0),
        # decode-bound regime (short in, long out): conservative
        # reservation starves admission; lazy growth shines
        "decode_heavy": make_trace(n, seed=2, mean_in=192, mean_out=640,
                                   max_out=2048, interval=0.0),
        # staggered arrivals near the paper's saturation point
        "arrivals": make_trace(max(n // 2, 20), seed=1, interval=1 / 7.0),
    }


def _run_worker(cfg, policy: str, reqs) -> Dict[str, float]:
    dev = DeviceModel(A10, cfg)
    eng = Engine(f"w-{policy}", cfg,
                 EngineConfig(max_batched_tokens=512, max_slots=256,
                              block_size=16,
                              num_kv_blocks=max(dev.kv_block_budget(16), 64),
                              sched_policy=policy),
                 dev, NullExecutor())
    runtime = ClusterRuntime([WorkerEndpoint("w", eng, queue_cap=None)],
                             RoundRobinRouter())
    m = runtime.run(reqs)
    m["goodput"] = goodput(reqs)
    m["preemptions"] = eng.n_preemptions
    return m


def _run_cronus(cfg, policy: str, reqs) -> Dict[str, float]:
    system = build_system("cronus", cfg, A100, A10, sched_policy=policy)
    m = system.run(reqs)
    m["goodput"] = goodput(reqs)
    m["preemptions"] = sum(e.n_preemptions for e in (system.ppi, system.cpi))
    return m


def run(n_requests: int = 300, arch: str = "llama3-8b",
        out_path: str = None) -> List[Dict]:
    cfg = get_config(arch)
    rows: List[Dict] = []
    for trace_name, trace in _traces(n_requests).items():
        for rig, runner in (("worker", _run_worker), ("cronus", _run_cronus)):
            for policy in POLICIES:
                reqs = trace.fresh()
                m = runner(cfg, policy, reqs)
                row = {"rig": rig, "trace": trace_name, "policy": policy,
                       "ttft_slo": DEFAULT_TTFT_SLO,
                       "tbt_slo": DEFAULT_TBT_SLO, **m}
                rows.append(row)
                print(f"sched_ablation/{rig}/{trace_name}/{policy},0,"
                      f"tput={m['throughput']:.3f} "
                      f"ttft_p99={m['ttft_p99']:.3f} "
                      f"tbt_p99={m['tbt_p99']:.4f} "
                      f"goodput={m['goodput']:.3f} "
                      f"preempt={m['preemptions']}")
    if out_path:
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"# wrote {out_path}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller request counts (CI smoke)")
    ap.add_argument("--n-requests", type=int, default=None)
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--out", default=None,
                    help="write rows as JSON (e.g. BENCH_scheduler_ablation.json)")
    args = ap.parse_args()
    n = args.n_requests or (80 if args.quick else 300)
    run(n_requests=n, arch=args.arch, out_path=args.out)


if __name__ == "__main__":
    main()
