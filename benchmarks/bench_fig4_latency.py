"""Paper Fig. 4: TTFT P99 and TBT P99 across approaches, fixed-interval
arrivals. Two operating points: light load (every system unsaturated — the
regime where disagg H-L shows the best possible TTFT) and near-saturation
(~85% of Cronus max throughput — where Cronus' TTFT/TBT advantages over
DP/PP express)."""
from __future__ import annotations

import time

from benchmarks.common import PAPER_GRID, paper_trace
from repro.configs import get_config
from repro.serving.hardware import DEVICES
from repro.serving.simulator import APPROACHES, run_approach


def run(n_requests: int = 400):
    print("name,us_per_call,derived")
    for hi, lo, arch in PAPER_GRID[:2]:  # one per model (runtime budget)
        cfg = get_config(arch)
        for regime, rate in (("light", 1.0), ("near_sat", 6.0)):
            reqs = paper_trace(n_requests, interval=1.0 / rate, seed=1)
            for approach in APPROACHES:
                t0 = time.time()
                m = run_approach(approach, cfg, DEVICES[hi], DEVICES[lo], reqs)
                wall = (time.time() - t0) * 1e6 / n_requests
                print(f"fig4/{hi}+{lo}/{arch}/{regime}/{approach},{wall:.1f},"
                      f"ttft_p99={m['ttft_p99']:.3f}s "
                      f"tbt_p99={m['tbt_p99']*1000:.1f}ms "
                      f"tput={m['throughput']:.2f}")


if __name__ == "__main__":
    run()
