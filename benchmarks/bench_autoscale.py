"""Elastic autoscaling under a diurnal ramp: SLO attainment per
device-second, autoscaled vs statically provisioned.

The experiment the autoscaler exists for: drive the same sinusoidal
ramp (``ramp:LO:HI:PERIOD`` — trough, peak, trough) through three rigs:

  * ``static_peak`` — a fixed fleet sized for the peak (cronus A100+A10
    pair + 2 A10 workers); meets the SLO everywhere but pays for peak
    capacity through the trough;
  * ``static_trough`` — the pair alone; cheap, but the peak buries it;
  * ``autoscaled`` — the pair plus an idle rack of 2 A10s, scaled by the
    SLO-driven policy loop (attach at the peak's queue build-up, detach
    in the trough's idle window).

Costs come from the autoscaler's :class:`DeviceLedger` (A100-equivalent
device-seconds, peak-FLOPS-normalized); static rigs are priced with the
same unit costs over their whole makespan. ``cost_efficiency`` — SLO-met
requests per A100-equivalent device-second — is the gated headline: the
autoscaled rig must match static-peak goodput while measurably beating
its cost, and this benchmark FAILS (exit 1) if it doesn't.

Template capacity seeds come from the committed open-loop capacity
search (BENCH_open_loop.json: cronus burst capacity ~5.3 QPS; the A10
worker uses the FLOPS-proportional prior).

Row keys for the regression gate: ``rig`` + ``trace``
(``ramp{LO}-{HI}@{PERIOD}s``).

Run: ``PYTHONPATH=src python -m benchmarks.bench_autoscale [--quick]
[--out BENCH_autoscale.json]``
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List

from benchmarks.common import DEFAULT_TBT_SLO, DEFAULT_TTFT_SLO
from repro.autoscale import (Autoscaler, DeviceInventory, EndpointTemplate,
                             UNIT_COST, endpoint_devices, parse_autoscale)
from repro.serving.api import ServeSpec
from repro.serving.trace import make_trace
from repro.workloads import OpenLoopDriver

RAMP_LO, RAMP_HI = 1.0, 12.0
RACK = "A100:1"                    # idle devices the autoscaler may use
POLICY = ("slo:goodput>=0.9:cooldown=8:window=8:up_age=1.0"
          ":down_busy=0.5:eval=0.5")
# find_capacity-derived seed for the pair (benchmarks/baselines/
# BENCH_open_loop.json: the bursty-arrival capacity — a ramp peak is
# closer to a burst than to smooth Poisson); workers use the
# FLOPS-proportional prior (A100 ~4.1 QPS, A10 ~1.6 QPS)
CAPACITY_SEED = {"cronus:A100+A10": 5.3125}

STATIC_RIGS = {
    "static_peak": "cronus:A100+A10,worker:A100",
    "static_trough": "cronus:A100+A10",
}

GATE_KEYS = ("throughput", "ttft_p99", "tbt_p99", "goodput", "completed")


def _arrival(period: float) -> str:
    return f"ramp:{RAMP_LO!r}:{RAMP_HI!r}:{period!r}"


def _static_cost(service) -> float:
    rate = sum(UNIT_COST[d] for ep in service.endpoints
               for d in endpoint_devices(ep))
    return rate * service.now


def _measure(rig: str, service, reqs, n: int, period: float) -> Dict:
    driver = OpenLoopDriver(service)
    driver.run(reqs)
    m = driver.metrics(DEFAULT_TTFT_SLO, DEFAULT_TBT_SLO)
    scaler = service.autoscaler
    if scaler is not None:
        rep = scaler.report(service.now)
        cost = rep["device_cost"]
        secs = rep["device_seconds"]
        extra = {"n_scale_ups": rep["n_scale_ups"],
                 "n_scale_downs": rep["n_scale_downs"],
                 "final_endpoints": rep["final_endpoints"]}
    else:
        cost = _static_cost(service)
        secs = {}
        for ep in service.endpoints:
            for d in endpoint_devices(ep):
                secs[d] = round(secs.get(d, 0.0) + service.now, 6)
        extra = {}
    row = {"rig": rig, "trace": f"ramp{RAMP_LO:g}-{RAMP_HI:g}@{period:g}s",
           "ttft_slo": DEFAULT_TTFT_SLO, "tbt_slo": DEFAULT_TBT_SLO,
           **{k: m[k] for k in GATE_KEYS},
           "device_seconds": secs, "device_cost": round(cost, 6),
           # the headline: SLO-met requests per A100-equivalent
           # device-second — capacity you paid for but didn't need counts
           # against you
           "cost_efficiency": round(m["goodput"] * n / cost, 6),
           **extra}
    print(f"autoscale/{rig},0,goodput={m['goodput']:.3f} "
          f"ttft_p99={m['ttft_p99']:.3f} cost={cost:.1f}A100s "
          f"eff={row['cost_efficiency']:.4f}"
          + (f" ups={extra['n_scale_ups']} downs={extra['n_scale_downs']}"
             if extra else ""))
    return row


def run(n: int, period: float, seed: int = 0,
        out_path: str = None) -> List[Dict]:
    arrival = _arrival(period)

    def fresh_requests():
        return make_trace(n, seed=seed, arrival=arrival)

    rows: List[Dict] = []
    for rig, cluster in STATIC_RIGS.items():
        service = ServeSpec(cluster=cluster, arrival=arrival).build()
        rows.append(_measure(rig, service, fresh_requests(), n, period))

    # same router as the cluster rigs: the single-pair default (weighted
    # round-robin) cannot weight endpoints that join after build, so an
    # elastic fleet needs load-aware routing
    service = ServeSpec(approach="cronus", arrival=arrival,
                        router="least_loaded").build()
    inv = DeviceInventory.parse(RACK)
    templates = [
        EndpointTemplate("worker:A100", 4.056),
        EndpointTemplate("cronus:A100+A10",
                         CAPACITY_SEED["cronus:A100+A10"]),
    ]
    service.attach_autoscaler(Autoscaler(
        inv, templates=templates, policy=parse_autoscale(POLICY)))
    rows.append(_measure("autoscaled", service, fresh_requests(), n, period))

    _enforce(rows)
    if out_path:
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"# wrote {out_path}")
    return rows


def _enforce(rows: List[Dict]) -> None:
    """The claim this benchmark gates: elasticity matches peak-sized
    goodput at measurably lower device cost."""
    by_rig = {r["rig"]: r for r in rows}
    auto, peak = by_rig["autoscaled"], by_rig["static_peak"]
    print(f"# autoscaled: goodput {auto['goodput']:.3f} vs peak "
          f"{peak['goodput']:.3f}, cost {auto['device_cost']:.1f} vs "
          f"{peak['device_cost']:.1f} A100-seconds")
    if auto["goodput"] < peak["goodput"] - 0.02:
        raise SystemExit(
            f"FAIL: autoscaled goodput {auto['goodput']:.3f} below "
            f"static-peak {peak['goodput']:.3f}")
    if auto["device_cost"] > 0.92 * peak["device_cost"]:
        raise SystemExit(
            f"FAIL: autoscaled device cost {auto['device_cost']:.1f} not "
            f"measurably below static-peak {peak['device_cost']:.1f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller trace / shorter ramp period (CI smoke)")
    ap.add_argument("--n-requests", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="write rows as JSON (e.g. BENCH_autoscale.json)")
    args = ap.parse_args()
    n = args.n_requests or (150 if args.quick else 400)
    period = 40.0 if args.quick else 90.0
    run(n=n, period=period, seed=args.seed, out_path=args.out)


if __name__ == "__main__":
    main()
