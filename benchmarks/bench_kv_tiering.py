"""Cluster-scale KV tiering: host-memory tier + KV-aware routing vs the
PR 3 prefix-affinity baseline on a cache-thrashing shared-prefix trace.

The rig is deliberately hostile to a GPU-only cache: four A10 workers
with 768-block pools (12288 cached tokens each) against a 48-group,
1024-token-prefix working set (~49k prefix tokens, ~4x one worker's
pool). Under pure GPU caching, prefix_affinity keeps the hit rate up by
*placement* — but every group that falls cold pays a full re-prefill.
The tiered configuration adds a host-memory tier behind each pool
(refcount-0 prefix blocks demote to host DRAM, promote back on a hit
with the PCIe cost charged into iteration time) and the kv_aware router,
which consults a cluster-wide prefix index plus live allocator probes
that see both tiers.

Two comparisons per trace density:

  * ``baseline``  — prefix_affinity router, GPU-only cache (PR 3 setup);
  * ``tiered``    — kv_aware router, host tier of HOST_KV_BLOCKS/worker.

The win condition (self-gated below, and regression-gated in CI via
``benchmarks/baselines/BENCH_kv_tiering.json``): tiered must beat
baseline on prefix_cache_hit_rate AND not lose on TTFT p99 — i.e. the
PCIe promotions it pays must cost less than the prefills it skips.

Run: ``PYTHONPATH=src python -m benchmarks.bench_kv_tiering
[--quick] [--out BENCH_kv_tiering.json]``
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List

from benchmarks.common import DEFAULT_TBT_SLO, DEFAULT_TTFT_SLO, goodput
from repro.cluster.router import KVAwareRouter, PrefixAffinityRouter
from repro.cluster.runtime import ClusterRuntime, WorkerEndpoint
from repro.configs import get_config
from repro.core.engine import Engine, EngineConfig
from repro.core.executor import NullExecutor
from repro.serving.hardware import A10, DeviceModel
from repro.serving.trace import make_shared_prefix_trace

# Same starved pools as bench_prefix_cache's cluster rig: each worker
# caches at most 768*16 = 12288 tokens.
WORKER_KV_BLOCKS = 768
# Host tier per worker: 4x the GPU pool (the @host DSL default), enough
# for each worker's share of the working set to survive demotion.
HOST_KV_BLOCKS = 3072
N_WORKERS = 4
N_PREFIXES = 48      # 48 * 1024 = ~49k prefix tokens, ~4x one GPU pool


def _trace(n: int, interval: float, seed: int = 0):
    return make_shared_prefix_trace(n, seed=seed, interval=interval,
                                    n_prefixes=N_PREFIXES, prefix_len=1024,
                                    mean_suffix_in=96, mean_out=24,
                                    max_out=64)


def _workers(cfg, host_blocks: int) -> List[WorkerEndpoint]:
    eps = []
    for i in range(N_WORKERS):
        eng = Engine(f"w{i}", cfg,
                     EngineConfig(max_slots=16,
                                  num_kv_blocks=WORKER_KV_BLOCKS,
                                  prefix_cache=True,
                                  host_kv_blocks=host_blocks),
                     DeviceModel(A10, cfg), NullExecutor())
        eps.append(WorkerEndpoint(f"w{i}", eng, queue_cap=None))
    return eps


def _run(cfg, mode: str, reqs) -> Dict[str, float]:
    if mode == "baseline":
        eps = _workers(cfg, 0)
        router = PrefixAffinityRouter()
    else:
        eps = _workers(cfg, HOST_KV_BLOCKS)
        router = KVAwareRouter()
    m = ClusterRuntime(eps, router).run(reqs)
    m["goodput"] = goodput(reqs)
    engines = [ep.engine for ep in eps]
    m["tokens_reused"] = sum(e.allocator.n_tokens_reused for e in engines)
    m["evictions"] = sum(e.allocator.n_evictions for e in engines)
    m["demotions"] = sum(e.allocator.n_demotions for e in engines)
    m["promotions"] = sum(e.allocator.n_promotions for e in engines)
    m["host_evictions"] = sum(e.allocator.n_host_evictions
                              for e in engines)
    return m


def run(n_requests: int = 400, arch: str = "llama3-8b",
        out_path: str = None) -> List[Dict]:
    cfg = get_config(arch)
    rows: List[Dict] = []
    results: Dict[tuple, Dict[str, float]] = {}

    for interval, label in ((0.3, "steady"), (0.15, "burst")):
        for mode in ("baseline", "tiered"):
            reqs = _trace(n_requests, interval)
            m = _run(cfg, mode, reqs)
            results[(label, mode)] = m
            row = {"rig": "cluster", "trace": f"shared_prefix_{label}",
                   "policy": mode,
                   "router": ("prefix_affinity" if mode == "baseline"
                              else "kv_aware"),
                   "cache": True, "ttft_slo": DEFAULT_TTFT_SLO,
                   "tbt_slo": DEFAULT_TBT_SLO, **m}
            rows.append(row)
            print(f"kv_tiering/{label}/{mode},0,"
                  f"tput={m['throughput']:.3f} "
                  f"ttft_p99={m['ttft_p99']:.4f} "
                  f"hit_rate={m.get('prefix_cache_hit_rate', 0.0):.3f} "
                  f"reused={m['tokens_reused']} "
                  f"demote={m['demotions']} promote={m['promotions']}")

    # Self-gate: the host tier must pay for itself on BOTH densities.
    for label in ("steady", "burst"):
        base, tier = results[(label, "baseline")], results[(label, "tiered")]
        hit_b = base.get("prefix_cache_hit_rate", 0.0)
        hit_t = tier.get("prefix_cache_hit_rate", 0.0)
        assert hit_t > hit_b, (
            f"{label}: tiered hit rate {hit_t:.3f} <= baseline {hit_b:.3f}")
        assert tier["ttft_p99"] <= base["ttft_p99"] * 1.02, (
            f"{label}: tiered ttft_p99 {tier['ttft_p99']:.4f} worse than "
            f"baseline {base['ttft_p99']:.4f}")
        print(f"# GATE {label}: hit {hit_b:.3f} -> {hit_t:.3f}, "
              f"ttft_p99 {base['ttft_p99']:.4f} -> {tier['ttft_p99']:.4f}")

    if out_path:
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"# wrote {out_path}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller request count (CI smoke / regression gate)")
    ap.add_argument("--n-requests", type=int, default=None)
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--out", default=None,
                    help="write rows as JSON (e.g. BENCH_kv_tiering.json)")
    args = ap.parse_args()
    n = args.n_requests or (160 if args.quick else 400)
    run(n_requests=n, arch=args.arch, out_path=args.out)


if __name__ == "__main__":
    main()
