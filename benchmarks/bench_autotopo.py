"""Auto-topology planner vs hand-written layouts: SLO capacity per
A100-equivalent device-cost on a small heterogeneous rack.

The claim the planner exists for: given a rack and a workload, the
searched topology beats what an operator writes by reflex. Two hand
baselines, both consuming the whole rack (that is the reflex):

  * ``hand_workers`` — every device a standalone chunked-prefill worker
    (the homogeneous data-parallel answer);
  * ``hand_pairs``   — greedily pair fastest+slowest into Cronus pairs,
    leftovers as workers (the all-pairs answer).

Each baseline is measured with its *better* router (round-robin vs
least-loaded), so the planner cannot win on router choice alone. The
planner searches the same rack with the same ``find_capacity`` prober
(same seeded probe traces) and must achieve >= {GATE}x the better
baseline's capacity-per-cost — this benchmark FAILS (exit 1) otherwise.
On this rack the winning move is structural: the A10s cannot hold the
tight TTFT SLO on Azure-length prompts, so layouts that spend them
(which both hand baselines must) pay 0.8 A100-equivalents for capacity
the A100 already had; the planner leaves them idle.

Costs are :class:`~repro.autoscale.inventory.DeviceLedger` pricing
(peak-FLOPS-normalized A100-seconds), the same meter bench_autoscale
settles with. ``cost_efficiency`` carries the gated score (capacity per
device-cost); ``throughput`` carries the capacity itself.

Row keys for the regression gate: ``rig``
(``planner_best | hand_workers | hand_pairs``) + ``trace``.

Run: ``PYTHONPATH=src python -m benchmarks.bench_autotopo [--quick]
[--out BENCH_autotopo.json]``
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

from repro.autotopo import Candidate, TopologyPlanner, WorkloadSpec, \
    hand_baselines

RACK = "A100:1,A10:2"
GATE = 1.1          # planner score must be >= GATE x best hand score
# tight-SLO capacity planning: 2 s TTFT / 0.1 s TBT is the regime where
# placement matters (at the default 5 s TTFT every layout on this rack
# saturates the probe bracket and scores identically)
TTFT_SLO, TBT_SLO = 2.0, 0.1

ROUTERS = ("round_robin", "least_loaded")


def _measure_hand(planner: TopologyPlanner, name: str,
                  layout: str) -> Dict:
    """A hand layout at its best router (fair-fight rule)."""
    best = None
    for router in ROUTERS:
        pc = planner.evaluate(Candidate(layout, router))
        if best is None or pc.score > best.score:
            best = pc
    return {"rig": name, "cluster": best.cluster, "router": best.router,
            "capacity_qps": round(best.capacity_qps, 6),
            "cost_rate": round(best.cost_rate, 6),
            "throughput": round(best.capacity_qps, 6),
            "cost_efficiency": round(best.score, 6)}


def run(n: int, seed: int = 0, out_path: str = None) -> List[Dict]:
    workload = WorkloadSpec(n_requests=n, seed=seed,
                            ttft_slo=TTFT_SLO, tbt_slo=TBT_SLO)
    trace_key = f"{workload.trace}-{workload.arrival}"
    t0 = time.time()
    planner = TopologyPlanner(RACK, workload, max_endpoints=3)
    plan = planner.plan()
    best = plan.best
    rows: List[Dict] = [{
        "rig": "planner_best", "trace": trace_key,
        "cluster": best.cluster, "router": best.router,
        "capacity_qps": round(best.capacity_qps, 6),
        "cost_rate": round(best.cost_rate, 6),
        "throughput": round(best.capacity_qps, 6),
        "cost_efficiency": round(best.score, 6),
        "n_evaluations": plan.n_evaluations,
        "n_probe_runs": sum(len(p["evaluations"]) for p in plan.probes),
    }]
    print(f"autotopo/planner_best,0,{best.cluster} via {best.router} "
          f"cap={best.capacity_qps:.2f}qps score={best.score:.3f} "
          f"({plan.n_evaluations} evals, {time.time() - t0:.0f}s)")
    # hand baselines share the planner's memo'd prober: same seeds, same
    # brackets, so the comparison is probe-for-probe fair
    for name, layout in sorted(hand_baselines(RACK).items()):
        row = _measure_hand(planner, f"hand_{name}", layout)
        row["trace"] = trace_key
        rows.append(row)
        print(f"autotopo/hand_{name},0,{row['cluster']} via "
              f"{row['router']} cap={row['capacity_qps']:.2f}qps "
              f"score={row['cost_efficiency']:.3f}")

    _enforce(rows)
    if out_path:
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"# wrote {out_path}")
    return rows


def _enforce(rows: List[Dict]) -> None:
    """The gated claim: searched placement beats both hand reflexes on
    capacity per device-cost by >= GATE x."""
    by_rig = {r["rig"]: r for r in rows}
    planner = by_rig["planner_best"]["cost_efficiency"]
    for name in ("hand_workers", "hand_pairs"):
        hand = by_rig[name]["cost_efficiency"]
        ratio = planner / hand if hand > 0 else float("inf")
        print(f"# planner {planner:.3f} vs {name} {hand:.3f} "
              f"({ratio:.2f}x, gate {GATE}x)")
        if planner < GATE * hand:
            raise SystemExit(
                f"FAIL: planner capacity-per-cost {planner:.3f} is not "
                f">= {GATE}x {name}'s {hand:.3f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller probe traces (CI smoke)")
    ap.add_argument("--n-requests", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="write rows as JSON (e.g. BENCH_autotopo.json)")
    args = ap.parse_args()
    n = args.n_requests or (60 if args.quick else 120)
    run(n=n, seed=args.seed, out_path=args.out)


if __name__ == "__main__":
    main()
