"""Cluster scaling: throughput + TTFT/TBT P99 vs cluster size and router
policy (the multi-instance dimension the paper's single-pair evaluation
leaves open — HexGen-2-style heterogeneous sets, vLLM-production-stack-style
routing).

Clusters scale 2 -> 6 engines by adding Cronus pairs (the 6-engine row
mixes A100+A10 and A100+A30 pairs — heterogeneous across AND within
pairs), replaying the same Azure-style trace under all three routers.
Expected shape: throughput grows and tail TTFT falls with pair count;
session affinity pays a modest tail penalty for stickiness.

The final ``naive_mix`` row adds bare A10 workers to a pair instead of
scaling pairs: a straggler lesson — in max-throughput mode the slow
standalone workers inflate the makespan and *reduce* measured throughput,
which is why scale-out here composes pairs rather than loose devices
(exactly the load-imbalance failure mode the paper's Table 3 documents for
naive disaggregation, resurfacing at cluster scope).
"""
from __future__ import annotations

import time

from benchmarks.common import emit_csv_row
from repro.cluster import build_cluster
from repro.cluster.router import ROUTERS
from repro.configs import get_config
from repro.serving.trace import make_trace

# (label, spec, #engines); rows 2+ are heterogeneous clusters
CLUSTERS = [
    ("pair1", "cronus:A100+A10", 2),
    ("pair2", "2xcronus:A100+A10", 4),
    ("pair3_het", "2xcronus:A100+A10,cronus:A100+A30", 6),
    ("naive_mix", "cronus:A100+A10,2xworker:A10", 4),
]


def run(n_requests: int = 300, arch: str = "llama3-8b",
        interval: float = 0.0, sessions: int = 32):
    cfg = get_config(arch)
    reqs = make_trace(n_requests, seed=0, interval=interval,
                      sessions=sessions)
    results = {}
    print("name,us_per_call,derived")
    for label, spec, n_engines in CLUSTERS:
        for router in sorted(ROUTERS):
            system = build_cluster(cfg, spec, router=router)
            assert len(system.engines) == n_engines
            t0 = time.time()
            m = system.run(reqs.fresh())
            wall = (time.time() - t0) * 1e6 / max(n_requests, 1)
            results[(label, router)] = m
            emit_csv_row(
                f"cluster_scaling/{label}({n_engines}eng)/{router}", wall,
                f"tput={m['throughput']:.2f}req/s "
                f"ttft_p99={m['ttft_p99']:.2f}s "
                f"tbt_p99={m['tbt_p99']*1e3:.1f}ms "
                f"completed={m['completed']}")
    # scaling headline: throughput of the biggest pair cluster vs one pair
    for router in sorted(ROUTERS):
        base = results[("pair1", router)]["throughput"]
        top = results[("pair3_het", router)]["throughput"]
        emit_csv_row(f"cluster_scaling_ratio/{router}", 0,
                     f"x{top / base:.2f} (2->6 engines)")
    return results


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=300)
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--interval", type=float, default=0.0,
                    help="arrival interval (s); 0 = all at t0")
    args = ap.parse_args()
    run(n_requests=args.n, arch=args.arch, interval=args.interval)
