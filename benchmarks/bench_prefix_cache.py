"""Prefix-cache ablation: cache on/off x routers on the shared-prefix trace.

Three rigs:
  * ``worker`` — one A10 chunked-prefill+decode instance with a small KV
    pool. Isolates the block cache itself: with caching on, repeated
    system prompts skip their prefill, so TTFT and throughput improve and
    the run reports a nonzero prefix_cache_hit_rate.
  * ``cluster`` — four A10 workers whose pools are each too small for the
    whole prefix working set. This is where routing matters: least-loaded
    dilutes every cache over all prefix groups, session affinity pins by
    tag, and prefix_affinity chases the longest cached prefix (probe +
    routing history) under a load guard.
  * ``cronus`` — the A100+A10 Balancer pair: a PPI hit shortens the
    low-end split-prefill portion, a CPI hit the chunked remainder, so
    caching compounds with partially disaggregated prefill.

Run: ``PYTHONPATH=src python -m benchmarks.bench_prefix_cache
[--quick] [--out BENCH_prefix_cache.json]``
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List

from benchmarks.common import DEFAULT_TBT_SLO, DEFAULT_TTFT_SLO, goodput
from repro.cluster.router import (LeastLoadedRouter, PrefixAffinityRouter,
                                  SessionAffinityRouter)
from repro.cluster.runtime import ClusterRuntime, WorkerEndpoint
from repro.configs import get_config
from repro.core.engine import Engine, EngineConfig
from repro.core.executor import NullExecutor
from repro.serving.hardware import A10, A100, DeviceModel
from repro.serving.simulator import build_system
from repro.serving.trace import make_shared_prefix_trace

# Small per-worker pools: the 32-group prefix working set deliberately
# exceeds one worker's cache, so router placement decides the hit rate.
WORKER_KV_BLOCKS = 768

ROUTERS = {
    "least_loaded": LeastLoadedRouter,
    "session": SessionAffinityRouter,
    "prefix_affinity": PrefixAffinityRouter,
}


def _trace(n: int, interval: float, n_prefixes: int = 32):
    """Prefill-dominated multi-tenant shape (long shared templates, short
    outputs) — the workload class where block-level prefix reuse pays.
    The cluster rig uses 32 prefix groups (working set >> one worker's
    pool, so routing decides the hit rate); the single-worker rig uses 8
    (fits its pool, isolating the cache itself)."""
    return make_shared_prefix_trace(n, seed=0, interval=interval,
                                    n_prefixes=n_prefixes, prefix_len=1024,
                                    mean_suffix_in=96, mean_out=24,
                                    max_out=64)


def _workers(cfg, n: int, cache: bool) -> List[WorkerEndpoint]:
    eps = []
    for i in range(n):
        eng = Engine(f"w{i}", cfg,
                     EngineConfig(max_slots=16,
                                  num_kv_blocks=WORKER_KV_BLOCKS,
                                  prefix_cache=cache),
                     DeviceModel(A10, cfg), NullExecutor())
        eps.append(WorkerEndpoint(f"w{i}", eng, queue_cap=None))
    return eps


def _cache_stats(engines) -> Dict[str, int]:
    return {
        "tokens_reused": sum(e.allocator.n_tokens_reused for e in engines),
        "evictions": sum(e.allocator.n_evictions for e in engines),
        "cow_copies": sum(e.allocator.n_cow_copies for e in engines),
    }


def _run_worker(cfg, cache: bool, reqs) -> Dict[str, float]:
    eps = _workers(cfg, 1, cache)
    m = ClusterRuntime(eps, LeastLoadedRouter()).run(reqs)
    m["goodput"] = goodput(reqs)
    m.update(_cache_stats([ep.engine for ep in eps]))
    return m


def _run_cluster(cfg, router: str, cache: bool, reqs) -> Dict[str, float]:
    eps = _workers(cfg, 4, cache)
    m = ClusterRuntime(eps, ROUTERS[router]()).run(reqs)
    m["goodput"] = goodput(reqs)
    m.update(_cache_stats([ep.engine for ep in eps]))
    return m


def _run_cronus(cfg, cache: bool, reqs) -> Dict[str, float]:
    system = build_system("cronus", cfg, A100, A10, max_slots=16,
                          prefix_cache=cache)
    m = system.run(reqs)
    m["goodput"] = goodput(reqs)
    m.update(_cache_stats([system.ppi, system.cpi]))
    return m


def run(n_requests: int = 400, arch: str = "llama3-8b",
        out_path: str = None) -> List[Dict]:
    cfg = get_config(arch)
    rows: List[Dict] = []

    def emit(rig, router, cache, m):
        row = {"rig": rig, "trace": "shared_prefix", "router": router,
               "cache": cache, "ttft_slo": DEFAULT_TTFT_SLO,
               "tbt_slo": DEFAULT_TBT_SLO, **m}
        rows.append(row)
        print(f"prefix_cache/{rig}/{router}/cache={int(cache)},0,"
              f"tput={m['throughput']:.3f} "
              f"ttft_p50={m['ttft_p50']:.4f} "
              f"ttft_p99={m['ttft_p99']:.4f} "
              f"hit_rate={m.get('prefix_cache_hit_rate', 0.0):.3f} "
              f"reused={m['tokens_reused']} evict={m['evictions']}")

    for cache in (False, True):
        reqs = _trace(max(n_requests // 4, 40), 0.8, n_prefixes=8)
        emit("worker", "least_loaded", cache, _run_worker(cfg, cache, reqs))
    for router in ("least_loaded", "session", "prefix_affinity"):
        for cache in ((False, True) if router == "least_loaded"
                      else (True,)):
            reqs = _trace(n_requests, 0.2)
            emit("cluster", router, cache,
                 _run_cluster(cfg, router, cache, reqs))
    for cache in (False, True):
        reqs = _trace(max(n_requests // 2, 40), 0.35)
        emit("cronus", "round_robin", cache, _run_cronus(cfg, cache, reqs))

    if out_path:
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"# wrote {out_path}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller request counts (CI smoke / regression gate)")
    ap.add_argument("--n-requests", type=int, default=None)
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--out", default=None,
                    help="write rows as JSON (e.g. BENCH_prefix_cache.json)")
    args = ap.parse_args()
    n = args.n_requests or (160 if args.quick else 400)
    run(n_requests=n, arch=args.arch, out_path=args.out)


if __name__ == "__main__":
    main()
