"""Performance regression gate: diff BENCH_*.json against committed baselines.

Each benchmark writes a list of row dicts. Rows are keyed by their
non-numeric fields (rig / trace / policy / router / cache); the gate then
compares the perf-critical numeric columns against the baseline row:

  * ``throughput`` / ``goodput`` — fail when the current value drops more
    than ``--tol`` below baseline;
  * ``ttft_p99`` / ``tbt_p99`` — fail when the current value rises more
    than ``--tol`` above baseline.

Rows present in the baseline but missing from the current run fail (lost
coverage); new rows only inform. All benchmark time is simulated
(roofline device models, deterministic traces), so values are stable
across machines and the gate can be tight.

Usage:
  python -m benchmarks.check_regression BENCH_foo.json [BENCH_bar.json ...] \\
      --baseline benchmarks/baselines [--tol 0.15]
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys

LOWER_IS_BAD = ("throughput", "goodput", "cost_efficiency")
HIGHER_IS_BAD = ("ttft_p99", "tbt_p99")
KEY_FIELDS = ("rig", "trace", "policy", "router", "cache")


def row_key(row):
    return tuple((f, row[f]) for f in KEY_FIELDS if f in row)


def index_rows(rows):
    out = {}
    for row in rows:
        key = row_key(row)
        if key in out:
            raise SystemExit(f"duplicate benchmark row key {key}")
        out[key] = row
    return out


def compare(name, current, baseline, tol):
    """Returns a list of structured failure records, one per drifted key:
    {file, row, metric, baseline, current, pct} (baseline/current/pct are
    None for rows missing from the current run). Every offending key is
    reported, not just the first, so multi-key drift reads as one table.
    """
    failures = []
    cur, base = index_rows(current), index_rows(baseline)
    for key, brow in base.items():
        label = "/".join(str(v) for _, v in key)
        crow = cur.get(key)
        if crow is None:
            failures.append(
                {
                    "file": name,
                    "row": label,
                    "metric": "(row)",
                    "baseline": None,
                    "current": None,
                    "pct": None,
                }
            )
            continue
        for col in LOWER_IS_BAD + HIGHER_IS_BAD:
            if col not in brow or col not in crow:
                continue
            b, c = float(brow[col]), float(crow[col])
            if math.isnan(b) or math.isnan(c):
                continue
            bad_drop = col in LOWER_IS_BAD and c < b * (1.0 - tol)
            bad_rise = col in HIGHER_IS_BAD and c > b * (1.0 + tol) and c - b > 1e-9
            if bad_drop or bad_rise:
                failures.append(
                    {
                        "file": name,
                        "row": label,
                        "metric": col,
                        "baseline": b,
                        "current": c,
                        "pct": (c - b) / b if b else math.inf,
                    }
                )
    for key in cur.keys() - base.keys():
        label = "/".join(str(v) for _, v in key)
        print(f"note: {name}: new row {label} (no baseline yet)")
    return failures


def format_drift_table(failures):
    """Aligned per-key drift table; one line per (file, row, metric)."""
    header = ("file", "row", "metric", "baseline", "current", "drift")
    rows = [header]
    for f in failures:
        if f["baseline"] is None:
            rows.append((f["file"], f["row"], f["metric"], "-", "missing", "-"))
        else:
            rows.append(
                (
                    f["file"],
                    f["row"],
                    f["metric"],
                    f"{f['baseline']:.4f}",
                    f"{f['current']:.4f}",
                    f"{f['pct']:+.1%}",
                )
            )
    widths = [max(len(r[i]) for r in rows) for i in range(len(header))]
    lines = []
    for r in rows:
        lines.append("  ".join(c.ljust(w) for c, w in zip(r, widths)).rstrip())
    lines.insert(1, "  ".join("-" * w for w in widths))
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("current", nargs="+", help="BENCH_*.json from this run")
    ap.add_argument(
        "--baseline",
        default="benchmarks/baselines",
        help="directory of committed baseline BENCH_*.json",
    )
    ap.add_argument(
        "--tol",
        type=float,
        default=0.15,
        help="allowed relative slack before failing (0.15 = 15%%)",
    )
    args = ap.parse_args(argv)

    failures = []
    for path in args.current:
        name = os.path.basename(path)
        base_path = os.path.join(args.baseline, name)
        if not os.path.exists(base_path):
            raise SystemExit(
                f"no baseline {base_path}; generate it with the benchmark's "
                f"--out and commit it under {args.baseline}/"
            )
        with open(path) as f:
            current = json.load(f)
        with open(base_path) as f:
            baseline = json.load(f)
        failures += compare(name, current, baseline, args.tol)

    if failures:
        print(
            f"\nFAIL: {len(failures)} perf regression(s) beyond "
            f"{args.tol:.0%} tolerance:\n"
        )
        print(format_drift_table(failures))
        return 1
    print(f"OK: all rows within {args.tol:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
