"""SLO-driven autoscaling policy for the live serving cluster.

The :class:`Autoscaler` closes the loop the paper leaves to operators:
given a rack of idle devices (:class:`~repro.autoscale.inventory
.DeviceInventory`) and the live cluster's windowed signals, decide each
tick window whether to attach a new endpoint, detach an idle one, or hold
— and, because the rack is heterogeneous, *which kind* of endpoint to
build (an A100+A10 Cronus pair vs a lone A10 worker), ranked by measured
SLO-sustainable capacity per A100-equivalent device-second.

Signals (all windowed, none global):

  * **queueing age** — the oldest queued request's age across endpoint
    queues and the service's pending deque. Age is the *leading* overload
    indicator: it crosses ``up_age`` several seconds before TTFT-misses
    show up in finished-request goodput.
  * **windowed goodput** — SLO attainment over requests that finished in
    the last ``window`` seconds; the trailing confirmation, and the guard
    that blocks scale-down while the SLO is in jeopardy.
  * **busy fraction** — per-endpoint work-per-wallclock over the last
    window (``EndpointStats.busy_frac``); the scale-down trigger.
  * **arrival rate** — submissions over the last window, used to size the
    capacity deficit at scale-up and the safety margin at scale-down.

Actuation goes through the membership surface this PR adds
(``attach_endpoint`` / ``detach_endpoint``): scale-down drains residents
by recompute back into the service's pending queue, so no request is ever
lost to a scaling action. Hysteresis comes from three places — distinct
up/down thresholds, a ``cooldown`` after every action, and the rule that
scale-down needs *both* idle busy-fractions and rate headroom.

Policies parse from compact spec strings (``"slo:goodput>=0.9:
cooldown=5"``) so they survive ServeSpec JSON/CLI round-trips.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.metrics import meets_slo
from repro.workloads.sweep import DEFAULT_TBT_SLO, DEFAULT_TTFT_SLO
from repro.autoscale.inventory import (DeviceInventory, DeviceLedger,
                                       EndpointTemplate, build_endpoint,
                                       endpoint_devices,
                                       heuristic_capacity_qps)


@dataclasses.dataclass(frozen=True)
class AutoscalePolicy:
    """Thresholds and pacing for the scaling loop. Defaults are tuned for
    the repo's simulated-hardware scale (TTFT SLO 5s): react to ~half an
    SLO of queueing, confirm idleness over a 10s window, and never act
    twice within a cooldown."""

    goodput_target: float = 0.9     # windowed SLO-attainment floor
    cooldown: float = 10.0          # min seconds between scaling actions
    window: float = 10.0            # signal window (rate/goodput/busy)
    up_age: float = 2.5             # oldest-queued age triggering scale-up
    down_busy: float = 0.35         # busy-fraction ceiling for scale-down
    down_headroom: float = 0.8      # post-detach capacity safety margin
    min_endpoints: int = 1          # never detach below this floor
    eval_every: float = 1.0         # min seconds between evaluations
    spinup: float = 0.0             # provisioning delay for new endpoints
    ttft_slo: float = DEFAULT_TTFT_SLO
    tbt_slo: float = DEFAULT_TBT_SLO

    def __post_init__(self):
        if not (0.0 < self.goodput_target <= 1.0):
            raise ValueError(f"goodput target must be in (0, 1], "
                             f"got {self.goodput_target}")
        if not (0.0 <= self.down_busy < 1.0):
            raise ValueError(f"down_busy must be in [0, 1), "
                             f"got {self.down_busy}")
        if not (0.0 < self.down_headroom <= 1.0):
            raise ValueError(f"down_headroom must be in (0, 1], "
                             f"got {self.down_headroom}")
        if self.min_endpoints < 1:
            raise ValueError("min_endpoints must be >= 1")
        for field in ("cooldown", "window", "up_age", "eval_every",
                      "spinup", "ttft_slo", "tbt_slo"):
            if getattr(self, field) < 0:
                raise ValueError(f"{field} must be >= 0")

    @property
    def spec(self) -> str:
        """Compact spec string; ``parse_autoscale(p.spec) == p``."""
        default = AutoscalePolicy()
        parts = ["slo"]
        if self.goodput_target != default.goodput_target:
            parts.append(f"goodput>={self.goodput_target!r}")
        for key, field in _POLICY_KEYS.items():
            if getattr(self, field) != getattr(default, field):
                parts.append(f"{key}={getattr(self, field)!r}")
        return ":".join(parts)


# spec-string key -> policy field (goodput>= handled separately)
_POLICY_KEYS = {
    "cooldown": "cooldown",
    "window": "window",
    "up_age": "up_age",
    "down_busy": "down_busy",
    "down_headroom": "down_headroom",
    "min": "min_endpoints",
    "eval": "eval_every",
    "spinup": "spinup",
    "ttft": "ttft_slo",
    "tbt": "tbt_slo",
}


def parse_autoscale(spec: str) -> AutoscalePolicy:
    """Parse ``"slo[:goodput>=G][:cooldown=C][:window=W][:up_age=A]
    [:down_busy=B][:down_headroom=H][:min=N][:eval=E][:spinup=S]
    [:ttft=T][:tbt=T]"``. Only the ``slo`` family exists today; the kind
    prefix keeps room for others (schedule-driven, predictive)."""
    parts = spec.split(":")
    if not parts or parts[0] != "slo":
        raise ValueError(f"unknown autoscale policy kind in {spec!r} "
                         "(expected 'slo[:key=value...]')")
    kw: Dict[str, object] = {}
    for part in parts[1:]:
        if not part:
            raise ValueError(f"empty clause in autoscale spec {spec!r}")
        if part.startswith("goodput>="):
            key, field, raw = "goodput>=", "goodput_target", part[9:]
        else:
            key, sep, raw = part.partition("=")
            if not sep or key not in _POLICY_KEYS:
                raise ValueError(
                    f"bad autoscale clause {part!r}; known keys: "
                    f"goodput>=, {', '.join(sorted(_POLICY_KEYS))}")
            field = _POLICY_KEYS[key]
        try:
            val = int(raw) if field == "min_endpoints" else float(raw)
        except ValueError:
            raise ValueError(f"bad number {raw!r} for autoscale key "
                             f"{key!r}") from None
        if field in kw:
            raise ValueError(f"duplicate autoscale key {key!r} in {spec!r}")
        kw[field] = val
    return AutoscalePolicy(**kw)


class Autoscaler:
    """The scaling loop. Bound to one ``InferenceService`` via
    ``service.attach_autoscaler(autoscaler)``; the service calls
    ``on_tick`` after every simulation tick, and the autoscaler throttles
    itself to ``policy.eval_every`` of simulated time.

    ``endpoint_factory(template, name) -> endpoint`` may be injected for
    tests; the default materialises the template's node string through
    ``build_endpoint`` with the config captured at bind time."""

    def __init__(self, inventory: DeviceInventory,
                 templates: Optional[List[EndpointTemplate]] = None,
                 policy: Optional[AutoscalePolicy] = None,
                 endpoint_factory: Optional[Callable] = None):
        self.inventory = inventory
        self.templates = templates
        self.policy = policy or AutoscalePolicy()
        self.ledger = DeviceLedger()
        self.events: List[Dict] = []     # scaling-action audit trail
        self._factory = endpoint_factory
        self._service = None
        self._capacity: Dict[str, float] = {}    # endpoint name -> QPS est
        self._devices: Dict[str, Tuple[str, ...]] = {}
        self._last_eval = float("-inf")
        self._last_action = float("-inf")
        self._n_added = 0
        self._rate_log: List[Tuple[float, int]] = []  # (now, n_submitted)

    # ------------------------------------------------------------------
    def bind(self, service) -> None:
        """Adopt the service's base fleet: open ledger leases at t=0 and
        seed capacity estimates (template match by device set, else the
        FLOPS prior) so the very first deficit computation is sane."""
        if self._service is not None and self._service is not service:
            raise ValueError("autoscaler is already bound to a service")
        self._service = service
        if self.templates is None:
            from repro.autoscale.inventory import default_templates
            self.templates = default_templates(self.inventory)
        by_devices = {tuple(sorted(t.devices)): t.capacity_qps
                      for t in self.templates}
        for ep in service.runtime.endpoints:
            devices = endpoint_devices(ep)
            self._devices[ep.name] = devices
            self._capacity[ep.name] = by_devices.get(
                tuple(sorted(devices)), heuristic_capacity_qps(devices))
            self.ledger.open(ep.name, devices, 0.0)

    def _build(self, template: EndpointTemplate, name: str):
        if self._factory is not None:
            return self._factory(template, name)
        return build_endpoint(self._service.cfg, template.node, name,
                              **self._service.build_kw)

    # ------------------------------------------------------------------
    def on_tick(self, service) -> Optional[str]:
        """Throttled evaluation; returns the name of the endpoint a
        scaling action touched, or None."""
        now = service.now
        if now - self._last_eval < self.policy.eval_every:
            return None
        self._last_eval = now
        return self.evaluate(service, now)

    # -- signals -------------------------------------------------------
    def _arrival_rate(self, service, now: float) -> float:
        self._rate_log.append((now, service.n_submitted))
        horizon = now - self.policy.window
        while len(self._rate_log) > 2 and self._rate_log[1][0] <= horizon:
            self._rate_log.pop(0)
        t0, n0 = self._rate_log[0]
        span = now - t0
        return (service.n_submitted - n0) / span if span > 0 else 0.0

    def _windowed_goodput(self, service, now: float
                          ) -> Tuple[Optional[float], int]:
        lo = now - self.policy.window
        recent = [r.metrics for ep in service.runtime.endpoints
                  for r in ep.finished() if r.metrics.finish_time >= lo]
        recent += [r.metrics for r in service.runtime.retired
                   if r.metrics.finish_time >= lo]
        if not recent:
            return None, 0
        ok = sum(meets_slo(m, self.policy.ttft_slo, self.policy.tbt_slo)
                 for m in recent)
        return ok / len(recent), len(recent)

    # -- the decision --------------------------------------------------
    def evaluate(self, service, now: float) -> Optional[str]:
        pol = self.policy
        rate = self._arrival_rate(service, now)   # must sample every eval
        if now - self._last_action < pol.cooldown:
            return None
        endpoints = service.runtime.endpoints
        stats = {ep.name: ep.stats() for ep in endpoints}
        max_age = max([s.oldest_queued_age for s in stats.values()],
                      default=0.0)
        head = service.oldest_pending_arrival()
        if head is not None:
            max_age = max(max_age, now - head)
        goodput, n_recent = self._windowed_goodput(service, now)
        capacity = sum(self._capacity.get(ep.name, 0.0) for ep in endpoints)

        slo_risk = (goodput is not None and n_recent >= 5
                    and goodput < pol.goodput_target)
        if max_age > pol.up_age or slo_risk:
            return self._scale_up(service, now, rate, capacity, max_age)

        idle = (max_age == 0.0 and head is None
                and (goodput is None or goodput >= pol.goodput_target))
        if idle and len(endpoints) > pol.min_endpoints:
            return self._scale_down(service, now, rate, capacity, stats)
        return None

    def _scale_up(self, service, now: float, rate: float,
                  capacity: float, max_age: float) -> Optional[str]:
        deficit = max(rate - capacity, 0.0)
        affordable = [t for t in self.templates
                      if self.inventory.can_build(t.devices)]
        if not affordable:
            return None
        covering = [t for t in affordable if t.capacity_qps >= deficit]
        if covering:
            # cheapest build that plugs the gap; capacity-per-cost breaks
            # ties among equally-priced options
            tpl = min(covering, key=lambda t: (t.cost_rate, -t.efficiency))
        else:
            # nothing covers the whole deficit: take the biggest step
            tpl = max(affordable, key=lambda t: t.capacity_qps)
        name = f"as{self._n_added}-{tpl.kind}"
        self._n_added += 1
        ep = self._build(tpl, name)
        self.inventory.take(tpl.devices)
        # lease opens at decision time (devices are committed now);
        # capacity arrives after the provisioning delay
        self.ledger.open(name, tpl.devices, now)
        service.attach_endpoint(ep, now=now + self.policy.spinup)
        self._devices[name] = tpl.devices
        self._capacity[name] = tpl.capacity_qps
        self._last_action = now
        self._event(dict(t=now, action="scale_up", endpoint=name,
                         node=tpl.node, rate=rate,
                         capacity=capacity, max_age=max_age))
        return name

    def _scale_down(self, service, now: float, rate: float,
                    capacity: float, stats: Dict) -> Optional[str]:
        pol = self.policy
        # candidates: endpoints idle enough to shed; never the last
        # `min_endpoints`, and prefer shedding the least-busy
        order = sorted(service.runtime.endpoints,
                       key=lambda ep: (stats[ep.name].busy_frac,
                                       -self._capacity.get(ep.name, 0.0)))
        for ep in order:
            s = stats[ep.name]
            if s.busy_frac >= pol.down_busy or s.queue_depth > 0:
                continue
            remaining = capacity - self._capacity.get(ep.name, 0.0)
            if rate > pol.down_headroom * remaining:
                continue        # detaching would leave too little margin
            service.detach_endpoint(ep.name)
            devices = self._devices.pop(ep.name)
            self._capacity.pop(ep.name, None)
            self.inventory.put(devices)
            self.ledger.close(ep.name, now)
            self._last_action = now
            self._event(dict(t=now, action="scale_down",
                             endpoint=ep.name, rate=rate,
                             capacity=capacity,
                             busy_frac=s.busy_frac))
            return ep.name
        return None

    def _event(self, event: Dict) -> None:
        """Record one scaling action: on the flight recorder's control
        track when tracing is on (the unified event schema — autoscale
        actions land beside submits/routes/attaches on the timeline),
        and always on the legacy ``events`` list, which ``report()``
        exposes as the compatibility view."""
        self.events.append(event)
        svc = self._service
        tracer = svc.runtime.tracer if svc is not None else None
        if tracer is not None:
            args = {k: v for k, v in event.items()
                    if k not in ("t", "action")}
            tracer.instant(tracer.control, event["action"], event["t"],
                           args, cat="autoscale")

    # ------------------------------------------------------------------
    def report(self, now: Optional[float] = None) -> Dict:
        """Cost + action summary for benchmarks: device-seconds by type,
        A100-equivalent cost, and the scaling audit trail."""
        if now is None:
            now = self._service.now if self._service is not None else 0.0
        return {
            "device_seconds": {
                d: round(s, 6)
                for d, s in sorted(self.ledger.device_seconds(now).items())},
            "device_cost": round(self.ledger.device_cost(now), 6),
            "n_scale_ups": sum(1 for e in self.events
                               if e["action"] == "scale_up"),
            "n_scale_downs": sum(1 for e in self.events
                                 if e["action"] == "scale_down"),
            "final_endpoints": (len(self._service.runtime.endpoints)
                                if self._service is not None else 0),
            "events": list(self.events),
        }
