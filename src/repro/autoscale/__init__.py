"""Elastic autoscaling: device inventory, endpoint templates,
device-second accounting, and the SLO-driven scaling loop that drives the
cluster's live attach/detach membership surface."""
from repro.autoscale.inventory import (DeviceInventory, DeviceLedger,
                                       EndpointTemplate, UNIT_COST,
                                       build_endpoint, default_templates,
                                       endpoint_devices,
                                       heuristic_capacity_qps)
from repro.autoscale.policy import (AutoscalePolicy, Autoscaler,
                                    parse_autoscale)

__all__ = [
    "AutoscalePolicy", "Autoscaler", "DeviceInventory", "DeviceLedger",
    "EndpointTemplate", "UNIT_COST", "build_endpoint", "default_templates",
    "endpoint_devices", "heuristic_capacity_qps", "parse_autoscale",
]
