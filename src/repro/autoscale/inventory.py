"""Device inventory, endpoint templates, and device-second accounting for
elastic autoscaling.

The scale-up question on a heterogeneous cluster is not just *whether* to
add capacity but *what kind*: an A100+A10 Cronus pair buys ~3.5x the
sustainable QPS of a lone A10 worker at ~3.5x the device cost, so the
right choice depends on the size of the deficit and on what the inventory
still holds. This module supplies the three pieces the policy loop
composes:

  * :class:`DeviceInventory` — counts of idle devices by type (the spare
    rack), with a ``"A100:1,A10:4"`` spec string for CLI/JSON round-trip;
  * :class:`EndpointTemplate` — a buildable endpoint kind (single-node
    topology-DSL string such as ``"cronus:A100+A10"`` or ``"worker:A10"``)
    plus its estimated SLO-sustainable capacity, normally seeded from
    ``repro.workloads.find_capacity`` measurements;
  * :class:`DeviceLedger` — device-seconds per device type, opened at
    scale-up and closed at scale-down, so a benchmark can report SLO
    attainment *per device-second* instead of pretending capacity is free.

Costs are normalized to A100-seconds (``UNIT_COST`` — peak-FLOPS ratio,
the same proxy the paper's §5.1 cost argument uses), so heterogeneous
fleets compare on one axis.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.serving.hardware import DEVICES

# relative cost of one device-second, normalized to the A100 (peak-FLOPS
# ratio — the capability proxy the paper's heterogeneity argument prices)
UNIT_COST: Dict[str, float] = {
    name: spec.flops / DEVICES["A100"].flops for name, spec in DEVICES.items()
}

# heuristic capacity prior: sustainable QPS scales roughly with aggregate
# peak FLOPS for this workload family; the coefficient is calibrated to
# the measured open-loop capacity of the cronus A100+A10 pair
# (~5.8 QPS / 437 TFLOPS — see benchmarks/baselines/BENCH_open_loop.json).
# Templates built from find_capacity measurements override this.
_QPS_PER_TFLOP = 0.013


def heuristic_capacity_qps(devices: Sequence[str]) -> float:
    """FLOPS-proportional capacity prior for a device set (QPS)."""
    return _QPS_PER_TFLOP * sum(DEVICES[d].flops for d in devices) / 1e12


def endpoint_devices(ep) -> Tuple[str, ...]:
    """Device-type names an endpoint occupies (one per engine; the fused
    PP engine runs on both devices of its pipeline)."""
    names: List[str] = []
    for eng in ep.engines:
        dev = eng.device
        spec = getattr(dev, "spec", None)
        if spec is not None:
            names.append(spec.name)
        else:       # PipelineDeviceModel: hi/lo DeviceSpecs, one engine
            for s in (getattr(dev, "hi", None), getattr(dev, "lo", None)):
                if s is not None:
                    names.append(s.name)
    return tuple(names)


@dataclasses.dataclass
class DeviceInventory:
    """Idle devices by type — what the autoscaler may still turn into
    endpoints. Mutated by ``take``/``put`` as endpoints attach/detach."""

    counts: Dict[str, int] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        for name, n in self.counts.items():
            if name not in DEVICES:
                raise ValueError(f"unknown device {name!r} in inventory; "
                                 f"choose from {sorted(DEVICES)}")
            if n < 0:
                raise ValueError(f"negative inventory count for {name!r}")
        self.counts = {k: v for k, v in self.counts.items() if v > 0}

    @classmethod
    def parse(cls, spec: str) -> "DeviceInventory":
        """``"A100:1,A10:4"`` -> inventory. Empty string = empty rack."""
        counts: Dict[str, int] = {}
        for part in filter(None, (p.strip() for p in spec.split(","))):
            dev, sep, n = part.partition(":")
            if not sep:
                raise ValueError(f"bad inventory entry {part!r} "
                                 "(expected DEVICE:COUNT)")
            try:
                count = int(n)
            except ValueError:
                raise ValueError(f"bad inventory count in {part!r}") from None
            counts[dev] = counts.get(dev, 0) + count
        return cls(counts)

    @property
    def spec(self) -> str:
        return ",".join(f"{d}:{n}" for d, n in sorted(self.counts.items()))

    @property
    def total(self) -> int:
        return sum(self.counts.values())

    def can_build(self, devices: Sequence[str]) -> bool:
        need: Dict[str, int] = {}
        for d in devices:
            need[d] = need.get(d, 0) + 1
        return all(self.counts.get(d, 0) >= n for d, n in need.items())

    def take(self, devices: Sequence[str]) -> None:
        if not self.can_build(devices):
            raise ValueError(f"inventory {self.spec!r} cannot supply "
                             f"{tuple(devices)}")
        for d in devices:
            self.counts[d] -= 1
        self.counts = {k: v for k, v in self.counts.items() if v > 0}

    def put(self, devices: Sequence[str]) -> None:
        for d in devices:
            if d not in DEVICES:
                raise ValueError(f"unknown device {d!r}")
            self.counts[d] = self.counts.get(d, 0) + 1


@dataclasses.dataclass(frozen=True)
class EndpointTemplate:
    """A buildable endpoint kind: one single-node topology-DSL string
    (``"cronus:A100+A10"``, ``"worker:A10@sarathi"``, ...) plus its
    estimated SLO-sustainable capacity. ``capacity_qps`` should come from
    :func:`repro.workloads.find_capacity` runs on the target workload;
    the FLOPS-proportional heuristic is the fallback prior."""

    node: str
    capacity_qps: float

    def __post_init__(self):
        if self.capacity_qps <= 0:
            raise ValueError(f"template {self.node!r} needs "
                             f"capacity_qps > 0, got {self.capacity_qps}")
        self._node_spec()       # raises on malformed node strings

    def _node_spec(self):
        from repro.cluster.topology import parse_cluster_spec
        spec = parse_cluster_spec(self.node)
        if len(spec.nodes) != 1 or spec.nodes[0].count != 1:
            raise ValueError(f"endpoint template needs exactly one node, "
                             f"got {self.node!r}")
        return spec.nodes[0]

    @property
    def kind(self) -> str:
        return self._node_spec().kind

    @property
    def devices(self) -> Tuple[str, ...]:
        node = self._node_spec()
        # the fused pp engine still occupies both devices
        return node.devices

    @property
    def cost_rate(self) -> float:
        """A100-equivalents this template burns per second attached."""
        return sum(UNIT_COST[d] for d in self.devices)

    @property
    def efficiency(self) -> float:
        """Capacity per A100-equivalent device-second — the ranking the
        scale-up decision optimizes when several templates would cover
        the deficit."""
        return self.capacity_qps / self.cost_rate


def default_templates(
        inventory: DeviceInventory,
        capacity_qps: Optional[Dict[str, float]] = None,
) -> List[EndpointTemplate]:
    """Template set derivable from an inventory: one standalone worker per
    device type, plus a Cronus pair of (fastest type, each slower type) —
    the paper's partially-disaggregated unit. ``capacity_qps`` maps node
    strings to measured capacities and overrides the FLOPS prior."""
    capacity_qps = capacity_qps or {}

    def cap(node: str, devices: Sequence[str]) -> float:
        return capacity_qps.get(node, heuristic_capacity_qps(devices))

    types = sorted(inventory.counts, key=lambda d: -DEVICES[d].flops)
    templates = [EndpointTemplate(f"worker:{t}", cap(f"worker:{t}", (t,)))
                 for t in types]
    hi = types[0] if types else None
    for lo in types[1:]:
        node = f"cronus:{hi}+{lo}"
        templates.append(EndpointTemplate(node, cap(node, (hi, lo))))
    return templates


def build_endpoint(cfg, node: str, name: str, *,
                   executor_factory: Optional[Callable] = None,
                   max_slots: int = 256, block_size: int = 16,
                   max_batched_tokens: int = 512,
                   sched_policy: str = "fcfs", prefix_cache: bool = False,
                   worker_queue_cap: Optional[int] = 4,
                   num_kv_blocks: Optional[int] = None,
                   host_kv_blocks: int = 0,
                   executor: str = "null"):
    """Materialise one endpoint from a single-node topology-DSL string,
    under a caller-chosen unique ``name`` (the builder's positional
    ``kind0`` names would collide with the live cluster's)."""
    from repro.cluster.topology import build_cluster
    system = build_cluster(
        cfg, node, executor_factory=executor_factory, max_slots=max_slots,
        block_size=block_size, max_batched_tokens=max_batched_tokens,
        sched_policy=sched_policy, prefix_cache=prefix_cache,
        worker_queue_cap=worker_queue_cap,
        num_kv_blocks=num_kv_blocks, host_kv_blocks=host_kv_blocks,
        executor=executor)
    (ep,) = system.endpoints
    ep.name = name
    return ep


class DeviceLedger:
    """Device-seconds per device type, accrued from the moment an
    endpoint's devices are committed (scale-up request) until they return
    to the rack (detach). ``finalize``/``report`` price still-open leases
    up to ``now``, so a run's cost is exact at any probe time."""

    def __init__(self):
        self._open: Dict[str, Tuple[Tuple[str, ...], float]] = {}
        self._closed: List[Tuple[Tuple[str, ...], float, float]] = []

    def open(self, name: str, devices: Sequence[str], t: float) -> None:
        if name in self._open:
            raise ValueError(f"ledger already has an open lease for "
                             f"{name!r}")
        self._open[name] = (tuple(devices), t)

    def close(self, name: str, t: float) -> None:
        devices, t0 = self._open.pop(name)
        self._closed.append((devices, t0, t))

    def device_seconds(self, now: float) -> Dict[str, float]:
        out: Dict[str, float] = {}
        leases = self._closed + [(d, t0, max(now, t0))
                                 for d, t0 in self._open.values()]
        for devices, t0, t1 in leases:
            for d in devices:
                out[d] = out.get(d, 0.0) + (t1 - t0)
        return out

    def device_cost(self, now: float) -> float:
        """Total A100-equivalent device-seconds up to ``now``."""
        return sum(UNIT_COST[d] * s
                   for d, s in self.device_seconds(now).items())
