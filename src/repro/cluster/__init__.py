"""Cluster runtime: many engines, one event loop, pluggable routing.

Layering (bottom-up):
  * ``repro.core.engine.Engine`` — one serving instance (device + executor).
  * ``repro.cluster.runtime.Endpoint`` — a routable unit: a standalone
    worker, or a Cronus PPI+CPI pair (``repro.cluster.pair``).
  * ``repro.cluster.runtime.ClusterRuntime`` — the event loop that advances
    the globally-lagging runnable engine and fires timed events (arrivals,
    KV-transfer completions).
  * ``repro.cluster.router`` — picks an endpoint per request (round-robin,
    least-loaded, session-affinity, prefix-affinity).
  * ``repro.cluster.topology`` — builds a whole heterogeneous cluster from
    a declarative spec such as ``"2xcronus:A100+A10,4xworker:A10"``.
"""
from repro.cluster.pair import CronusPairEndpoint
from repro.cluster.router import (LeastLoadedRouter, PrefixAffinityRouter,
                                  Router, RoundRobinRouter,
                                  SessionAffinityRouter, make_router)
from repro.cluster.runtime import (ClusterRuntime, Endpoint, EndpointStats,
                                   WorkerEndpoint)
from repro.cluster.topology import (ClusterSpec, ClusterSystem, NodeSpec,
                                    build_cluster, canonical_cluster_spec,
                                    parse_cluster_spec)

__all__ = [
    "ClusterRuntime", "Endpoint", "EndpointStats", "WorkerEndpoint",
    "CronusPairEndpoint",
    "Router", "RoundRobinRouter", "LeastLoadedRouter",
    "SessionAffinityRouter", "PrefixAffinityRouter", "make_router",
    "ClusterSpec", "NodeSpec", "ClusterSystem", "build_cluster",
    "parse_cluster_spec", "canonical_cluster_spec",
]
