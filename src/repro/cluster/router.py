"""Pluggable request routers for the cluster runtime.

A router picks, for each arriving request, which endpoint (Cronus pair /
DP worker / disaggregated pool) serves it. ``select`` returns ``None``
when the chosen endpoint cannot take the request yet — the runtime then
retries after engines advance (head-of-line order, matching the dispatch
discipline of the per-system loops this subsystem replaced).

Policies:
  * :class:`RoundRobinRouter` — optionally weighted (paper §5.1's DP
    baseline uses weights 3:1 for A100:A10); probes endpoints in pattern
    order starting after the previous placement.
  * :class:`LeastLoadedRouter` — smallest queue depth first, most free KV
    blocks (via ``Engine.stats``) as the tie-break, so an empty cluster
    routes to the endpoint with the deepest free KV pool.
  * :class:`SessionAffinityRouter` — requests carrying a ``session`` stick
    to the endpoint that served the session first (KV reuse locality for
    multi-turn conversations); session-less requests and first turns fall
    through to an inner policy (least-loaded by default). A pin is not
    eternal: a home endpoint that keeps rejecting, or that is drastically
    more loaded than the best alternative, triggers a rebalance (the
    session re-pins through the fallback policy).
  * :class:`PrefixAffinityRouter` — routes each request to the endpoint
    holding the longest cached prefix of its prompt (live engine probe
    via ``Endpoint.cached_prefix_tokens``, backed by the router's own
    routing history), so shared system prompts and multi-turn sessions
    concentrate where their KV already lives; cache-cold requests fall
    through to least-loaded, and a load guard keeps affinity from
    convoying a hot endpoint.
  * :class:`KVAwareRouter` — cluster-level prefix *content* index: one
    global chain-hash map of which endpoints hold which prefix blocks
    (GPU or host tier), strengthened by the live allocator probe, with
    an optional cross-endpoint prefix fetch through the cluster
    transfer engine when load forces a request away from its KV.
"""
from __future__ import annotations

import abc
from collections import OrderedDict
from typing import List, Optional, Sequence

from repro.cluster.runtime import Endpoint
from repro.core.request import Request
from repro.kvcache.allocator import _chain


class Router(abc.ABC):
    """Routing policy: pick an endpoint for each dispatched request."""

    @abc.abstractmethod
    def select(self, req: Request,
               endpoints: Sequence[Endpoint]) -> Optional[Endpoint]:
        """Endpoint to serve ``req``, or ``None`` to retry later."""

    def on_membership_change(self, endpoints: Sequence[Endpoint]) -> None:
        """The cluster attached or detached an endpoint (elastic
        autoscaling): drop or remap any per-endpoint routing state so the
        next ``select`` neither KeyErrors nor routes to a ghost. Stateless
        routers need nothing; the default is a no-op."""


class RoundRobinRouter(Router):
    """Rotate over the endpoints, optionally weighted (dp's pattern)."""

    def __init__(self, weights: Optional[List[int]] = None):
        self.weights = weights
        self._pattern: Optional[List[int]] = None
        self._idx = 0

    def _pat(self, n: int) -> List[int]:
        if self._pattern is None:
            w = self.weights or [1] * n
            if len(w) != n:
                raise ValueError(f"{len(w)} weights for {n} endpoints")
            self._pattern = [i for i, wi in enumerate(w) for _ in range(wi)]
        return self._pattern

    def select(self, req, endpoints):
        """Next accepting endpoint in the (weighted) rotation."""
        pat = self._pat(len(endpoints))
        for probe in range(len(pat)):
            ep = endpoints[pat[(self._idx + probe) % len(pat)]]
            if ep.can_accept(req):
                self._idx = (self._idx + probe + 1) % len(pat)
                return ep
        return None

    def on_membership_change(self, endpoints):
        """Rebuild the rotation for the new fleet size."""
        # the pattern is positional, so it must be rebuilt for the new
        # membership; explicit weights were given for a specific fleet
        # size and cannot be remapped onto a different one — degrade to
        # uniform rotation rather than raising on the next select
        self._pattern = None
        self._idx = 0
        if self.weights is not None and len(self.weights) != len(endpoints):
            self.weights = None


class LeastLoadedRouter(Router):
    """Shallowest queue first; ties broken by free KV, then position."""

    def select(self, req, endpoints):
        """Accepting endpoint with the shallowest queue."""
        best, best_key = None, None
        for i, ep in enumerate(endpoints):
            if not ep.can_accept(req):
                continue
            s = ep.stats()
            key = (s.queue_depth, -s.free_kv_blocks, i)
            if best_key is None or key < best_key:
                best, best_key = ep, key
        return best


class SessionAffinityRouter(Router):
    """Pin each conversation (``req.session``) to one endpoint for KV
    locality, rebalancing via the fallback when the home endpoint stalls
    or runs ``imbalance``x deeper than the best alternative."""

    # a sticky head whose home endpoint is full returns None; let the
    # runtime place up to this many queued requests past it so one pinned
    # session doesn't convoy the whole arrival queue
    lookahead = 64

    def __init__(self, fallback: Optional[Router] = None,
                 max_stalls: int = 4, imbalance: float = 8.0):
        self.fallback = fallback or LeastLoadedRouter()
        self.max_stalls = max_stalls   # consecutive home rejections tolerated
        self.imbalance = imbalance     # home queue depth vs best alternative
        self._table = {}   # session id -> endpoint
        self._stalls = {}  # session id -> consecutive deferred selects

    def _overloaded(self, home, req, endpoints) -> bool:
        """Staleness escape hatch: a pin is worth KV locality only while
        the home endpoint is roughly competitive. If its queue runs
        ``imbalance``x deeper than the best alternative that would take
        the request, migrating (and re-prefilling) beats waiting."""
        others = [ep.stats().queue_depth for ep in endpoints
                  if ep is not home and ep.can_accept(req)]
        if not others:
            return False
        return home.stats().queue_depth > self.imbalance * (min(others) + 1)

    def select(self, req, endpoints):
        """The session's home endpoint, or a fresh pin via the fallback."""
        sess = getattr(req, "session", None)
        if sess is not None and sess in self._table:
            ep = self._table[sess]
            if ep.can_accept(req) and not self._overloaded(ep, req,
                                                           endpoints):
                self._stalls.pop(sess, None)
                return ep
            # home endpoint full or overloaded: tolerate a few stalls for
            # KV locality, then rebalance the session via the fallback
            # (the old behaviour pinned forever, convoying the session
            # behind the one most-loaded endpoint)
            stalls = self._stalls.get(sess, 0) + 1
            self._stalls[sess] = stalls
            if stalls <= self.max_stalls and not self._overloaded(
                    ep, req, endpoints):
                return None
        ep = self.fallback.select(req, endpoints)
        if ep is not None and sess is not None:
            self._table[sess] = ep
            self._stalls.pop(sess, None)
        return ep

    def on_membership_change(self, endpoints):
        """Un-home sessions whose endpoint left the cluster."""
        # un-home sessions whose endpoint left the cluster: they re-pin
        # through the fallback on their next request instead of sticking
        # to (and stalling on) a ghost endpoint
        live = set(map(id, endpoints))
        dead = [s for s, ep in self._table.items() if id(ep) not in live]
        for s in dead:
            del self._table[s]
            self._stalls.pop(s, None)
        self.fallback.on_membership_change(endpoints)


class PrefixAffinityRouter(Router):
    """Route toward the endpoint holding the longest cached prefix of the
    request's prompt (vLLM-production-stack-style prefix-aware routing);
    cache-cold requests fall back to least-loaded.

    Two affinity signals, the stronger wins:

      * the *live probe* — ``Endpoint.cached_prefix_tokens`` walks each
        endpoint's actual prefix index (exact, but blind to requests the
        runtime dispatched ahead of the simulated clock, whose KV is not
        cached yet);
      * *routing history* — block-grained chain hashes of every prompt
        this router placed, kept per endpoint (the production-stack
        trick: the router's own record of where a prefix went predicts
        where its KV lives, without asking the engines).

    ``min_match`` ignores trivially short matches that aren't worth
    skewing load for, and ``max_imbalance`` caps how much deeper than the
    least-loaded alternative the matched endpoint's queue may run — a hit
    saves one prefix prefill, not an unbounded wait behind a hot spot."""

    def __init__(self, fallback: Optional[Router] = None,
                 min_match: int = 16, max_imbalance: int = 4,
                 history_per_endpoint: int = 8192):
        self.fallback = fallback or LeastLoadedRouter()
        self.min_match = min_match
        self.max_imbalance = max_imbalance
        self.history_per_endpoint = history_per_endpoint
        # keyed by endpoint NAME, not list position: positions shift when
        # the cluster attaches/detaches endpoints (elastic autoscaling),
        # and a positional table would silently credit one endpoint with
        # another's routing history
        self._history: Dict[str, OrderedDict] = {}   # name -> hash -> True

    def _prompt_hashes(self, req, block_size: int) -> List[bytes]:
        hashes, h = [], b""
        prompt = req.prompt
        for lo in range(0, len(prompt) - block_size + 1, block_size):
            h = _chain(h, prompt[lo:lo + block_size])
            hashes.append(h)
        return hashes

    def _history_match(self, name: str, hashes: List[bytes],
                      block_size: int) -> int:
        seen = self._history.get(name)
        if seen is None:
            return 0
        n = 0
        for h in hashes:
            if h not in seen:
                break
            n += block_size
        return n

    def _record(self, name: str, hashes: List[bytes]):
        seen = self._history.setdefault(name, OrderedDict())
        for h in hashes:
            seen.pop(h, None)
            seen[h] = True                       # re-insert at MRU end
        while len(seen) > self.history_per_endpoint:
            seen.popitem(last=False)

    def select(self, req, endpoints):
        """Longest-cached-prefix endpoint, under the load guard."""
        bs = endpoints[0].engines[-1].ecfg.block_size
        hashes = self._prompt_hashes(req, bs)
        cands = [ep for ep in endpoints if ep.can_accept(req)]
        if not cands:
            return None
        best, best_len = None, self.min_match - 1
        for ep in cands:
            n = max(ep.cached_prefix_tokens(req),
                    self._history_match(ep.name, hashes, bs))
            if n > best_len:
                best, best_len = ep, n
        if best is not None:
            # affinity is only worth the skew while the matched endpoint
            # is roughly competitive on load
            floor = min(ep.stats().queue_depth for ep in cands)
            if best.stats().queue_depth <= floor + self.max_imbalance:
                self._record(best.name, hashes)
                return best
        ep = self.fallback.select(req, endpoints)
        if ep is not None:
            self._record(ep.name, hashes)
        return ep

    def on_membership_change(self, endpoints):
        """Forget detached endpoints' routing histories."""
        # forget detached endpoints' histories (their KV left with them);
        # a re-attached name starts cold, which is exactly its cache state
        live = {ep.name for ep in endpoints}
        for name in [n for n in self._history if n not in live]:
            del self._history[name]
        self.fallback.on_membership_change(endpoints)


class KVAwareRouter(Router):
    """Cluster-level prefix index: route each request to the endpoint
    whose KV caches — GPU *or* host tier — hold the longest prefix of its
    prompt (Mooncake/Dynamo-style KV-aware scheduling).

    Where :class:`PrefixAffinityRouter` keeps a per-endpoint *routing
    history* (where prompts were sent), this router maintains one global
    chain-hash index of where prefix *content* lives, updated on every
    placement — so two endpoints that both hold a hot prefix are both
    credited, and eviction-driven staleness is bounded by the live probe
    (``Endpoint.cached_prefix_tokens`` walks the real allocator indexes,
    including host-demoted chains) taken as the stronger of the two
    signals.

    Optionally (``fetch=True``) a routed-away request triggers a
    *cross-endpoint prefix fetch*: when the best-matching endpoint loses
    to the load guard, the chosen endpoint's allocator adopts the matched
    prefix through the cluster :class:`~repro.kvcache.TransferEngine`
    (kind ``prefix_fetch``, wire time on the destination's link) so the
    hot prefix replicates to where traffic actually lands. The fetch is a
    cache warm — it gates no request — and models KV movement only, so it
    is limited to the simulated (``executor="null"``) path: real paged
    pools would need cross-pool page copies.
    """

    def __init__(self, fallback: Optional[Router] = None,
                 min_match: int = 16, max_imbalance: int = 4,
                 index_size: int = 65536, fetch: bool = False,
                 min_fetch: int = 512):
        self.fallback = fallback or LeastLoadedRouter()
        self.min_match = min_match
        self.max_imbalance = max_imbalance
        self.index_size = index_size
        self.fetch = fetch
        self.min_fetch = min_fetch
        self._index: OrderedDict = OrderedDict()   # hash -> {endpoint names}
        self._runtime = None
        self.n_fetches = 0

    def bind_runtime(self, runtime) -> None:
        """Called by :class:`~repro.cluster.runtime.ClusterRuntime` on
        construction: gives the router access to the cluster transfer
        engine for prefix fetches."""
        self._runtime = runtime

    # ------------------------------------------------------------------
    def _prompt_hashes(self, req, block_size: int) -> List[bytes]:
        hashes, h = [], b""
        prompt = req.prompt
        for lo in range(0, len(prompt) - block_size + 1, block_size):
            h = _chain(h, prompt[lo:lo + block_size])
            hashes.append(h)
        return hashes

    def _index_match(self, name: str, hashes: List[bytes],
                     block_size: int) -> int:
        n = 0
        for h in hashes:
            holders = self._index.get(h)
            if not holders or name not in holders:
                break
            n += block_size
        return n

    def _record(self, name: str, hashes: List[bytes]):
        for h in hashes:
            holders = self._index.get(h)
            if holders is None:
                self._index[h] = {name}
            else:
                holders.add(name)
                self._index.move_to_end(h)
        while len(self._index) > self.index_size:
            self._index.popitem(last=False)

    def _maybe_fetch(self, req, src: Endpoint, dst: Endpoint,
                     n_tokens: int, hashes: List[bytes]) -> None:
        if (not self.fetch or self._runtime is None
                or n_tokens < self.min_fetch):
            return
        eng = dst.engines[-1]
        if eng.ecfg.executor != "null" or not eng.ecfg.prefix_cache:
            return
        alloc = eng.allocator
        self._runtime.transfers.transfer(
            req, src=src.name, dst=dst.name,
            deliver=lambda r, a=alloc, n=n_tokens: a.adopt_prefix(r.prompt, n),
            when=max(req.arrival, src.stats().clock),
            n_tokens=n_tokens, device_model=eng.device,
            charge="link", kind="prefix_fetch")
        self._record(dst.name, hashes)
        self.n_fetches += 1

    # ------------------------------------------------------------------
    def select(self, req, endpoints):
        """Best KV-holding endpoint (index + two-tier probe), under the
        load guard; optionally fetches the prefix to the loaded choice."""
        bs = endpoints[0].engines[-1].ecfg.block_size
        hashes = self._prompt_hashes(req, bs)
        cands = [ep for ep in endpoints if ep.can_accept(req)]
        if not cands:
            return None
        best, best_len = None, self.min_match - 1
        for ep in cands:
            n = max(ep.cached_prefix_tokens(req),
                    self._index_match(ep.name, hashes, bs))
            if n > best_len:
                best, best_len = ep, n
        if best is not None:
            floor = min(ep.stats().queue_depth for ep in cands)
            if best.stats().queue_depth <= floor + self.max_imbalance:
                self._record(best.name, hashes)
                return best
        ep = self.fallback.select(req, endpoints)
        if ep is not None:
            if best is not None and ep is not best:
                # the prefix lives on `best` but load pushed the request
                # to `ep`: optionally replicate the hot prefix over there
                self._maybe_fetch(req, best, ep, best_len, hashes)
            self._record(ep.name, hashes)
        return ep

    def on_membership_change(self, endpoints):
        """Scrub detached endpoints out of the content index."""
        # scrub detached endpoints out of the content index (their pools
        # left with them); entries with no holder left disappear
        live = {ep.name for ep in endpoints}
        for h in list(self._index):
            holders = self._index[h] & live
            if holders:
                self._index[h] = holders
            else:
                del self._index[h]
        self.fallback.on_membership_change(endpoints)


ROUTERS = {
    "round_robin": RoundRobinRouter,
    "least_loaded": LeastLoadedRouter,
    "session": SessionAffinityRouter,
    "prefix_affinity": PrefixAffinityRouter,
    "kv_aware": KVAwareRouter,
}


def make_router(name: str, **kw) -> Router:
    """Instantiate a registered router by name (see ``ROUTERS``)."""
    try:
        return ROUTERS[name](**kw)
    except KeyError:
        raise KeyError(f"unknown router {name!r}; "
                       f"choose from {sorted(ROUTERS)}") from None
