"""Pluggable request routers for the cluster runtime.

A router picks, for each arriving request, which endpoint (Cronus pair /
DP worker / disaggregated pool) serves it. ``select`` returns ``None``
when the chosen endpoint cannot take the request yet — the runtime then
retries after engines advance (head-of-line order, matching the dispatch
discipline of the per-system loops this subsystem replaced).

Policies:
  * :class:`RoundRobinRouter` — optionally weighted (paper §5.1's DP
    baseline uses weights 3:1 for A100:A10); probes endpoints in pattern
    order starting after the previous placement.
  * :class:`LeastLoadedRouter` — smallest queue depth first, most free KV
    blocks (via ``Engine.stats``) as the tie-break, so an empty cluster
    routes to the endpoint with the deepest free KV pool.
  * :class:`SessionAffinityRouter` — requests carrying a ``session`` stick
    to the endpoint that served the session first (KV reuse locality for
    multi-turn conversations); session-less requests and first turns fall
    through to an inner policy (least-loaded by default).
"""
from __future__ import annotations

import abc
from typing import List, Optional, Sequence

from repro.cluster.runtime import Endpoint
from repro.core.request import Request


class Router(abc.ABC):
    @abc.abstractmethod
    def select(self, req: Request,
               endpoints: Sequence[Endpoint]) -> Optional[Endpoint]:
        """Endpoint to serve ``req``, or ``None`` to retry later."""


class RoundRobinRouter(Router):
    def __init__(self, weights: Optional[List[int]] = None):
        self.weights = weights
        self._pattern: Optional[List[int]] = None
        self._idx = 0

    def _pat(self, n: int) -> List[int]:
        if self._pattern is None:
            w = self.weights or [1] * n
            if len(w) != n:
                raise ValueError(f"{len(w)} weights for {n} endpoints")
            self._pattern = [i for i, wi in enumerate(w) for _ in range(wi)]
        return self._pattern

    def select(self, req, endpoints):
        pat = self._pat(len(endpoints))
        for probe in range(len(pat)):
            ep = endpoints[pat[(self._idx + probe) % len(pat)]]
            if ep.can_accept(req):
                self._idx = (self._idx + probe + 1) % len(pat)
                return ep
        return None


class LeastLoadedRouter(Router):
    def select(self, req, endpoints):
        best, best_key = None, None
        for i, ep in enumerate(endpoints):
            if not ep.can_accept(req):
                continue
            s = ep.stats()
            key = (s.queue_depth, -s.free_kv_blocks, i)
            if best_key is None or key < best_key:
                best, best_key = ep, key
        return best


class SessionAffinityRouter(Router):
    # a sticky head whose home endpoint is full returns None; let the
    # runtime place up to this many queued requests past it so one pinned
    # session doesn't convoy the whole arrival queue
    lookahead = 64

    def __init__(self, fallback: Optional[Router] = None):
        self.fallback = fallback or LeastLoadedRouter()
        self._table = {}   # session id -> endpoint

    def select(self, req, endpoints):
        sess = getattr(req, "session", None)
        if sess is not None and sess in self._table:
            ep = self._table[sess]
            # sticky: wait for the home endpoint rather than migrate KV
            return ep if ep.can_accept(req) else None
        ep = self.fallback.select(req, endpoints)
        if ep is not None and sess is not None:
            self._table[sess] = ep
        return ep


ROUTERS = {
    "round_robin": RoundRobinRouter,
    "least_loaded": LeastLoadedRouter,
    "session": SessionAffinityRouter,
}


def make_router(name: str, **kw) -> Router:
    try:
        return ROUTERS[name](**kw)
    except KeyError:
        raise KeyError(f"unknown router {name!r}; "
                       f"choose from {sorted(ROUTERS)}") from None
