"""The Cronus PPI+CPI pair as a cluster endpoint.

This is the per-pair protocol of paper §4.2 (steps 1-7), extracted verbatim
from the old ``CronusSystem.run`` loop so that any number of pairs can sit
behind one :class:`~repro.cluster.runtime.ClusterRuntime`:

  (1) on submit, pull CPI stats;
  (2) Balancer chooses the partial prefill length L_p;
  (3) dispatch R[:L_p] to the PPI (<= ``max_ppi_requests`` resident);
  (4) PPI completion surfaces in ``ppi.completed_prefills`` — ``pump``
      turns each into a timed KV-transfer-completion event;
  (5-7) the event delivers the request (with its KV payload) to the CPI,
      whose next iteration ingests the transfer overlapped with compute.

Decode offload (paper §6, bounded by ``max_offload_frac``) keeps requests
whose prefill fell back to the full prompt on the PPI — they re-enter the
PPI as local-payload decoders instead of crossing to the CPI.

The disaggregated baselines are this same endpoint with a FixedBalancer
(partial length pinned to L_in) and a decode-only CPI.

The pair inherits its engines' batch-composition policy
(``EngineConfig.sched_policy``, threaded through ``build_cronus`` /
the topology DSL's ``@policy`` suffix): under a lazy policy the CPI
reserves prompt-only KV and grows it per decode step, which makes the
free-block count the Balancer pulls in step (1) reflect *actual* cache
use instead of the conservative full-context reservation — Alg. 1's
fallback (full prefill on the PPI) then fires only under real pressure.
"""
from __future__ import annotations

import copy
from typing import List, Tuple

from repro.core.engine import Engine
from repro.core.request import ReqState, Request
from repro.cluster.runtime import Endpoint


class CronusPairEndpoint(Endpoint):
    """One PPI+CPI Cronus pair as a routable endpoint: owns the paper's
    per-request protocol (balancer split, ≤2 in the PPI, KV handoff,
    bounded decode offload) over two engines."""

    def __init__(self, name: str, ppi: Engine, cpi: Engine, balancer,
                 max_ppi_requests: int = 2, decode_offload: bool = False,
                 max_offload_frac: float = 0.5):
        self.name = name
        self.ppi = ppi
        self.cpi = cpi
        self.balancer = balancer
        self.max_ppi_requests = max_ppi_requests
        self.decode_offload = decode_offload
        self.max_offload_frac = max_offload_frac
        self._in_ppi = {}       # ppi view req_id -> original request
        self._offloaded = set()

    @property
    def engines(self) -> Tuple[Engine, ...]:
        """(PPI, CPI) — decode engine last by Endpoint convention."""
        # decode engine last: Endpoint.sched_policy / EndpointStats read
        # the pair's policy and free-KV signal from the CPI
        return (self.ppi, self.cpi)

    # ------------------------------------------------------------------
    def _ppi_prefill_load(self) -> int:
        # offloaded decoders don't count against the paper's <=2 cap
        return len(self._in_ppi) + sum(
            1 for r in self.ppi.queue if r.req_id not in self._offloaded
            and r.req_id not in self._in_ppi)

    def can_accept(self, req: Request) -> bool:
        """Whether the PPI has room under the paper's ≤2-requests cap."""
        load = self._ppi_prefill_load()
        if load >= self.max_ppi_requests:
            return False
        # a future arrival may only claim an *idle* PPI (its clock then
        # jumps to the arrival); a busy PPI makes the router wait
        return req.arrival <= self.ppi.clock or load == 0

    def submit(self, req: Request, runtime=None):
        """Dispatch one request through the pair protocol (steps 1-3):
        pull CPI stats, choose the split, start the partial prefill."""
        self.ppi.clock = max(self.ppi.clock, req.arrival)
        stats = self.cpi.stats()                            # step (1)
        l_p = self.balancer.partial_prefill_length(          # step (2)
            req.input_len, stats)
        req.partial_len = int(l_p)
        tracer = runtime.tracer if runtime is not None else None
        if tracer is not None:
            tracer.instant(
                tracer.control, "balancer_split", self.ppi.clock,
                {"req": req.req_id, "endpoint": self.name,
                 "l_p": req.partial_len, "input_len": req.input_len,
                 "cpi_n_decode": stats.n_decode,
                 "cpi_free_kv_blocks": stats.free_kv_blocks})
        if (self.decode_offload and l_p >= req.input_len
                and not self.balancer.__class__.__name__.startswith("Fixed")):
            # Alg. 1 fell back (CPI out of KV blocks) -> offload the whole
            # request to the PPI (§6), but only while the PPI keeps
            # >= (1 - max_offload_frac) of its KV pool free for prefills
            alloc = self.ppi.allocator
            need = alloc.blocks_needed(req.input_len + req.output_len)
            budget = int(alloc.num_blocks * self.max_offload_frac)
            used = alloc.num_blocks - alloc.num_free
            if used + need <= budget:
                self._offloaded.add(req.req_id)
        view = copy.copy(req)                                # step (3)
        view.prompt = req.prompt[:req.partial_len]
        view.output_len = 0
        view.ready_time = req.arrival
        view.state = ReqState.WAITING
        view.context_len = 0
        self._in_ppi[view.req_id] = req
        self.ppi.add_request(view)

    # ------------------------------------------------------------------
    def pump(self, runtime=None):
        """Steps (4-5): each completed PPI prefill becomes a KV-transfer
        completion event that delivers the request to the CPI (or back to
        the PPI for offloaded decoders). The transfer *cost* is charged by
        the receiving engine when it ingests the payload (steps 6-7)."""
        while self.ppi.completed_prefills:
            t_done, view = self.ppi.completed_prefills.pop(0)
            orig = self._in_ppi.pop(view.req_id, None)
            if orig is None:
                continue                     # cancelled while in the PPI
            orig.partial_len = view.context_len
            orig.context_len = view.context_len
            orig.kv_payload = view.kv_payload
            orig.first_token = view.first_token
            orig.ready_time = t_done
            if orig.req_id in self._offloaded:
                orig.local_payload = True        # KV never leaves the PPI
                target, dst = self.ppi, "ppi"
            else:
                target, dst = self.cpi, "cpi"
            if runtime is not None:
                # the cluster transfer engine posts the delivery at t_done
                # and re-checks the terminal state in its closure: a cancel
                # landing between post and drain must not resurrect the
                # request in the receiving queue. Cost stays charge="ingest"
                # — the receiving engine prices the wire when it ingests
                # the payload (steps 6-7), overlapped with compute.
                runtime.transfers.transfer(
                    orig, src=f"{self.name}/ppi", dst=f"{self.name}/{dst}",
                    deliver=target.add_request, when=t_done,
                    n_tokens=0 if orig.local_payload else None,
                    kind="handoff")
            else:
                target.add_request(orig)

    def drain(self) -> List[Request]:
        """Evict the pair's whole population for recompute elsewhere
        (endpoint detach). Requests live in three places:

          * as a *view* in the PPI (queued, mid-prefill, or completed but
            unpumped in ``completed_prefills``) — the view is discarded
            and the original recomputes from scratch (its partial KV
            lives on the departing PPI, so the handoff cannot complete);
          * delivered to the CPI (queued handoff, TRANSFER, PREFILL, or
            decoding) — residents leave via preemption-by-recompute
            (generated tokens folded into the prompt), queued handoffs
            drop their payload;
          * as an offloaded decoder back on the PPI — same as the CPI
            case.

        Returns the displaced originals, stripped of every pair-local
        artifact, ready to re-route anywhere."""
        displaced: List[Request] = []
        for rid, orig in list(self._in_ppi.items()):
            del self._in_ppi[rid]
            self._offloaded.discard(rid)
            if self.ppi.remove_request(rid) is None:
                # the view finished its partial prefill and awaits pump:
                # drop it (its PPI blocks were freed at completion)
                self.ppi.completed_prefills = [
                    (t, v) for t, v in self.ppi.completed_prefills
                    if v.req_id != rid]
            orig.partial_len = 0
            orig.kv_payload = None
            orig.first_token = None
            orig.local_payload = False
            orig.context_len = 0
            orig.state = ReqState.WAITING
            orig.ready_time = orig.arrival
            displaced.append(orig)
        for eng in (self.cpi, self.ppi):
            for r in eng.drain_requests():
                self._offloaded.discard(r.req_id)
                displaced.append(r)
        return displaced

    def migrate(self) -> List[Request]:
        """Detach with KV carried out as migration payloads. PPI prefill
        views with computed KV (completed-but-unpumped handoffs, or
        mid-prefill residents) are folded back into their originals as
        partial payloads — exactly the state a Cronus handoff would have
        shipped — and CPI/PPI residents leave via
        :meth:`~repro.core.engine.Engine.migrate_requests`. Requests with
        nothing extractable strip to recompute, as in :meth:`drain`."""
        displaced: List[Request] = []
        for rid, orig in list(self._in_ppi.items()):
            del self._in_ppi[rid]
            self._offloaded.discard(rid)
            done = next(((t, v) for t, v in self.ppi.completed_prefills
                         if v.req_id == rid), None)
            if done is not None:
                # finished partial prefill awaiting pump: its payload is
                # already extracted — complete the handoff into the
                # original (PPI blocks were freed at completion)
                t_done, view = done
                self.ppi.completed_prefills = [
                    (t, v) for t, v in self.ppi.completed_prefills
                    if v.req_id != rid]
                orig.partial_len = view.context_len
                orig.context_len = view.context_len
                orig.kv_payload = view.kv_payload
                orig.first_token = view.first_token
                orig.ready_time = t_done
            else:
                view = self._find_view(rid)
                k = view.context_len if view is not None else 0
                if k > 0 and view.slot is not None:
                    # mid-prefill resident: carry the chunks computed so
                    # far (extract BEFORE remove frees the block table)
                    orig.kv_payload = self.ppi.executor.extract_kv(
                        view.slot, k)
                    orig.partial_len = k
                    orig.context_len = k
                    orig.first_token = None
                    orig.ready_time = max(orig.arrival, self.ppi.clock)
                else:
                    orig.partial_len = 0
                    orig.kv_payload = None
                    orig.first_token = None
                    orig.context_len = 0
                    orig.ready_time = orig.arrival
                self.ppi.remove_request(rid)
            orig.local_payload = False
            orig.state = ReqState.WAITING
            displaced.append(orig)
        for eng in (self.cpi, self.ppi):
            for r in eng.migrate_requests():
                self._offloaded.discard(r.req_id)
                displaced.append(r)
        return displaced

    def _find_view(self, rid: str):
        for r in self.ppi.slots:
            if r is not None and r.req_id == rid:
                return r
        for r in self.ppi.queue:
            if r.req_id == rid:
                return r
        return None

    def accepts_kv(self, req: Request) -> bool:
        """Migrated KV lands on the CPI directly (the PPI's job — partial
        prefill — already happened on the source), so the PPI admission
        cap doesn't gate it. A decode-only CPI can't chunk-prefill the
        remainder, so there the payload must cover the whole prompt."""
        if self.cpi.ecfg.decode_only and req.context_len < req.input_len:
            return False
        return True

    def submit_kv(self, req: Request, runtime=None):
        """Ingest a migrated request on the decode side."""
        # straight to the CPI, ready_time untouched (the migration
        # transfer gated delivery; ingest prices the wire)
        self.cpi.add_request(req)

    def cancel(self, req: Request) -> bool:
        """Mid-flight cancel across the pair: the request may live as a
        PPI prefill view (queued, resident, or completed-but-unpumped),
        as a delivered handoff on the CPI, or as an offloaded decoder
        back on the PPI."""
        rid = req.req_id
        orig = self._in_ppi.pop(rid, None)
        if orig is not None:
            self._offloaded.discard(rid)
            if self.ppi.cancel(rid) is None:
                # the view already finished its partial prefill and sits
                # in completed_prefills waiting for pump: drop it there
                # (its PPI blocks were freed at completion)
                self.ppi.completed_prefills = [
                    (t, v) for t, v in self.ppi.completed_prefills
                    if v.req_id != rid]
                orig.metrics.cancelled = True
                orig.metrics.cancel_time = self.ppi.clock
                if self.ppi.tracer is not None:
                    tracer = self.ppi.tracer
                    tracer.instant(self.ppi.trace_track, "cancel",
                                   self.ppi.clock, {"req": rid})
                    tracer.async_end(tracer.control, "request",
                                     self.ppi.clock, rid,
                                     {"cancelled": True})
            orig.state = ReqState.CANCELLED
            orig.kv_payload = None
            return True
        for eng in (self.cpi, self.ppi):
            if eng.cancel(rid) is not None:
                return True
        return False

    def finished(self) -> List[Request]:
        """Completions from both engines (offloaded decoders finish on
        the PPI)."""
        return list(self.cpi.finished) + list(self.ppi.finished)

    def n_finished(self) -> int:
        """Count of completions from both engines."""
        return len(self.cpi.finished) + len(self.ppi.finished)
