"""Declarative cluster topology: compose N heterogeneous devices into any
mix of Cronus pairs, standalone workers, disaggregated pools and pipeline
stages, fronted by one router.

A spec is a list of :class:`NodeSpec` (or the compact string DSL):

    "2xcronus:A100+A10,4xworker:A10"
        -> two Cronus PPI(A10)+CPI(A100) pairs and four standalone A10
           chunked-prefill workers behind one router.

    "cronus:A100+A10@sarathi,2xworker:A10@sjf"
        -> per-endpoint scheduling policies: the ``@policy`` suffix picks
           the iteration-level batch-composition policy for that node's
           engines (see ``repro.scheduling.SCHEDULERS``; default fcfs).

    "2xworker:A10@sarathi@cache"
        -> ``@cache`` turns on shared-prefix KV reuse for that node's
           engines (``EngineConfig.prefix_cache``); combine with
           ``router="prefix_affinity"`` so requests chase their prefix.

    "4xworker:A10@cache@host"
        -> ``@host`` adds a host-memory cache tier behind each engine's
           GPU pool (``EngineConfig.host_kv_blocks``): refcount-0 prefix
           blocks demote to host DRAM instead of being dropped and
           promote back on a hit, PCIe cost charged. Requires caching
           (``@cache`` or the cluster-wide ``prefix_cache``); sized
           4x the GPU pool unless ``host_kv_blocks`` is given globally.

Node kinds:
  * ``cronus:HI+LO``    — Balancer-split pair, prefill on LO, decode on HI
  * ``disagg_lh:HI+LO`` — full prefill on LO, decode-only HI
  * ``disagg_hl:HI+LO`` — full prefill on HI, decode-only LO
  * ``worker:DEV``      — standalone chunked-prefill+decode instance
                          (alias: ``dp``)
  * ``pp:HI+LO``        — two-stage pipeline fused into one engine

``build_cluster`` turns a spec into a :class:`ClusterSystem` whose
``run(requests)`` replays a trace through the shared event loop. A
single-``cronus`` spec builds exactly the engines ``build_cronus`` builds,
so a 1-pair cluster reproduces ``CronusSystem`` results to the bit.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Dict, List, Optional, Tuple, Union

from repro.cluster.router import Router, make_router
from repro.cluster.runtime import ClusterRuntime, Endpoint, WorkerEndpoint
from repro.core.engine import Engine, EngineConfig
from repro.scheduling import SCHEDULERS
from repro.serving.hardware import DEVICES, DeviceModel

PAIR_KINDS = ("cronus", "disagg_lh", "disagg_hl")
NODE_KINDS = PAIR_KINDS + ("worker", "pp")

_NODE_RE = re.compile(
    r"^(?:(\d+)x)?([a-z_]+):([A-Za-z0-9+]+)((?:@[a-z_]+)*)$")


@dataclasses.dataclass(frozen=True)
class NodeSpec:
    """One parsed ``[count x]kind:devices[@options]`` node of a cluster
    spec."""

    kind: str                       # one of NODE_KINDS
    devices: Tuple[str, ...]        # ("A100", "A10") for pairs, ("A10",) ...
    count: int = 1
    options: Dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        kind = "worker" if self.kind == "dp" else self.kind
        object.__setattr__(self, "kind", kind)
        if kind not in NODE_KINDS:
            raise ValueError(f"unknown node kind {self.kind!r}")
        if self.count < 1:
            raise ValueError(f"node count must be >= 1, got {self.count}")
        want = 1 if kind == "worker" else 2
        if len(self.devices) != want:
            raise ValueError(f"{kind} takes {want} device(s), "
                             f"got {self.devices}")
        for d in self.devices:
            if d not in DEVICES:
                raise ValueError(f"unknown device {d!r}; "
                                 f"choose from {sorted(DEVICES)}")
        policy = self.options.get("sched_policy")
        if policy is not None and policy not in SCHEDULERS:
            raise ValueError(f"unknown sched policy {policy!r}; "
                             f"choose from {sorted(SCHEDULERS)}")

    @property
    def suffixes(self) -> str:
        """The node's ``@`` option suffixes in canonical order (policy,
        then ``cache``, then ``host``)."""
        parts = []
        if "sched_policy" in self.options:
            parts.append(self.options["sched_policy"])
        if self.options.get("prefix_cache"):
            parts.append("cache")
        if self.options.get("host_tier"):
            parts.append("host")
        return "".join(f"@{p}" for p in parts)

    @property
    def spec(self) -> str:
        """This node as a canonical DSL segment
        (``[Nx]kind:dev[+dev][@suffixes]``); ``parse_cluster_spec`` on it
        reproduces the node."""
        count = f"{self.count}x" if self.count > 1 else ""
        return f"{count}{self.kind}:{'+'.join(self.devices)}{self.suffixes}"


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """A parsed cluster DSL string: node list + router choice."""

    nodes: Tuple[NodeSpec, ...]
    router: str = "least_loaded"

    @property
    def n_engines(self) -> int:
        """Engines the spec materialises (pairs count 2, pp fuses to 1)."""
        per = {"worker": 1, "pp": 1}
        return sum(per.get(n.kind, 2) * n.count for n in self.nodes)

    @property
    def spec(self) -> str:
        """The node list back as a DSL string (node order preserved)."""
        return ",".join(n.spec for n in self.nodes)


def parse_cluster_spec(text: str, router: str = "least_loaded") -> ClusterSpec:
    """Parse the compact DSL, e.g.
    ``"2xcronus:A100+A10,4xworker:A10@sarathi@cache"``. ``@`` suffixes
    stack: a scheduling-policy name picks the node's batch-composition
    policy, the literal ``cache`` enables shared-prefix KV reuse and
    ``host`` puts a host-memory cache tier behind the GPU pool.

    Every parse error is a one-line ``ValueError`` naming the offending
    segment and its character position in ``text``, so a typo deep in a
    long spec is found without bisecting the string by hand."""
    nodes = []
    offset = 0
    for i, raw in enumerate(text.split(","), start=1):
        part = raw.strip()
        pos = offset + (len(raw) - len(raw.lstrip()))
        offset += len(raw) + 1          # +1 for the consumed comma
        if not part:
            continue
        where = f"segment {i} at char {pos} ({part!r})"
        m = _NODE_RE.match(part)
        if m is None:
            raise ValueError(f"bad node spec in {where}: expected "
                             "[<count>x]<kind>:<dev>[+<dev>][@<policy>]"
                             "[@cache][@host]")
        count, kind, devs, suffixes = m.groups()
        options: Dict = {}
        for suffix in filter(None, (suffixes or "").split("@")):
            if suffix == "cache":
                options["prefix_cache"] = True
            elif suffix == "host":
                options["host_tier"] = True
            elif suffix in SCHEDULERS:
                options["sched_policy"] = suffix
            else:
                raise ValueError(
                    f"bad node spec in {where}: unknown suffix @{suffix} — "
                    f"expected 'cache', 'host' or a policy from "
                    f"{sorted(SCHEDULERS)}")
        try:
            nodes.append(NodeSpec(kind=kind, devices=tuple(devs.split("+")),
                                  count=int(count or 1), options=options))
        except ValueError as e:
            raise ValueError(f"bad node spec in {where}: {e}") from None
    if not nodes:
        raise ValueError(f"empty cluster spec {text!r}")
    return ClusterSpec(nodes=tuple(nodes), router=router)


def canonical_cluster_spec(spec: Union["ClusterSpec", str]) -> str:
    """One canonical DSL string per *isomorphic* topology.

    Two specs that materialise the same endpoint multiset — regardless of
    node order, count grouping (``"worker:A10,worker:A10"`` vs
    ``"2xworker:A10"``) or suffix spelling order (``@cache@sarathi`` vs
    ``@sarathi@cache``) — canonicalise to the same string: nodes are
    expanded, grouped by (kind, devices, options) and re-emitted sorted.
    The auto-topology planner keys its search-space dedupe and its
    evaluation memo on this string, so a layout is never measured twice
    under different spellings. Only DSL-expressible options participate
    (programmatic ``NodeSpec.options`` keys like ``queue_cap`` are not
    spellable and raise on round-trip)."""
    if isinstance(spec, str):
        spec = parse_cluster_spec(spec)
    groups: Dict[Tuple, int] = {}
    for node in spec.nodes:
        key = (node.kind, node.devices,
               tuple(sorted(node.options.items())))
        groups[key] = groups.get(key, 0) + node.count
    merged = [NodeSpec(kind=k, devices=d, count=n, options=dict(o))
              for (k, d, o), n in groups.items()]
    merged.sort(key=lambda x: (x.kind, x.devices, x.suffixes))
    text = ",".join(n.spec for n in merged)
    reparsed = parse_cluster_spec(text)
    if {(n.kind, n.devices, tuple(sorted(n.options.items()))): n.count
            for n in reparsed.nodes} != groups:
        raise ValueError(f"cluster spec does not round-trip through the "
                         f"DSL (programmatic node options?): {text!r}")
    return text


# ---------------------------------------------------------------------------
# builder
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ClusterSystem:
    """A built cluster: endpoints + router, run through the shared loop."""
    endpoints: List[Endpoint]
    router: Router

    @property
    def engines(self) -> List[Engine]:
        """Every engine across every endpoint."""
        return [e for ep in self.endpoints for e in ep.engines]

    def finished(self):
        """Completed requests across the whole cluster."""
        return [r for ep in self.endpoints for r in ep.finished()]

    def run(self, requests, max_steps: int = 10_000_000):
        """Replay a trace through a fresh runtime; aggregate metrics."""
        return ClusterRuntime(self.endpoints, self.router).run(
            requests, max_steps)

    def service(self):
        """This cluster as an online :class:`repro.serving.api.
        InferenceService` (submit/stream/cancel). Lazy import: the api
        module sits above the cluster layer."""
        from repro.serving.api import InferenceService
        return InferenceService(self.endpoints, self.router, system=self)


def _null_factory(role: str):
    from repro.core.executor import NullExecutor
    return NullExecutor()


def build_cluster(cfg, spec: Union[ClusterSpec, str], *,
                  router: Optional[Union[str, Router]] = None,
                  executor_factory: Optional[Callable] = None,
                  max_slots: int = 256, block_size: int = 16,
                  max_batched_tokens: int = 512,
                  worker_queue_cap: Optional[int] = 4,
                  sched_policy: str = "fcfs",
                  prefix_cache: bool = False,
                  num_kv_blocks: Optional[int] = None,
                  host_kv_blocks: int = 0,
                  executor: str = "null") -> ClusterSystem:
    """Materialise a :class:`ClusterSpec` into engines + endpoints.

    ``executor_factory(role)`` is called with ``"ppi"``/``"cpi"`` for pair
    engines and ``"worker"``/``"pp"`` for standalone ones (None -> real
    compute off, roofline timing only).

    ``sched_policy`` is the cluster-wide default batch-composition policy;
    a node's ``@policy`` DSL suffix (``options["sched_policy"]``)
    overrides it per endpoint. ``prefix_cache`` likewise is the
    cluster-wide default for shared-prefix KV reuse, overridden per node
    by the ``@cache`` suffix. ``num_kv_blocks`` overrides every engine's
    device-HBM-derived KV pool size (required with ``executor="paged"``,
    whose pool is materialized for real); ``executor`` names the compute
    backend the factory builds so each EngineConfig records it.

    ``host_kv_blocks`` > 0 adds a host-memory cache tier of that many
    blocks behind every *cached* node's engines; a node's ``@host``
    suffix opts in per node (sized 4x the node's GPU pool when no global
    size is given). ``@host`` on a node without prefix caching raises —
    the tier holds demoted prefix-cache content.
    """
    # imported lazily: core.cronus/baselines import the cluster runtime
    from repro.core.balancer import Balancer
    from repro.core.baselines import PipelineDeviceModel
    from repro.core.cronus import build_cronus, build_disaggregated
    from repro.core.predictor import profile_chunked, profile_prefill

    if isinstance(spec, str):
        spec = parse_cluster_spec(spec)
    executor_factory = executor_factory or _null_factory
    kw = dict(executor_factory=executor_factory, max_slots=max_slots,
              block_size=block_size, max_batched_tokens=max_batched_tokens,
              num_kv_blocks=num_kv_blocks, executor=executor)

    def pool(device) -> int:
        """Per-engine GPU KV pool size (override or HBM-derived)."""
        return (num_kv_blocks if num_kv_blocks is not None
                else max(device.kv_block_budget(block_size), 64))

    def host_tier(node, cache: bool, gpu_pool: int) -> int:
        """Host-tier blocks for a node: @host default 4x the GPU pool,
        global ``host_kv_blocks`` overrides; requires caching."""
        tier = node.options.get("host_tier", False)
        if tier and not cache:
            raise ValueError(
                f"node {node.kind}:{'+'.join(node.devices)}: @host requires "
                "prefix caching (@cache suffix or prefix_cache=True) — the "
                "host tier holds demoted prefix-cache content")
        if not cache or not (tier or host_kv_blocks):
            return 0
        return host_kv_blocks if host_kv_blocks else 4 * gpu_pool

    endpoints: List[Endpoint] = []
    for node in spec.nodes:
        policy = node.options.get("sched_policy", sched_policy)
        cache = node.options.get("prefix_cache", prefix_cache)
        for i in range(node.count):
            name = f"{node.kind}{len(endpoints)}"
            if node.kind in PAIR_KINDS:
                hi_spec, lo_spec = (DEVICES[d] for d in node.devices)
                hi, lo = DeviceModel(hi_spec, cfg), DeviceModel(lo_spec, cfg)
                # host tier sized off the decode-side pool (where the
                # shared-prefix working set actually lives)
                decode_model = lo if node.kind == "disagg_hl" else hi
                host = host_tier(node, cache, pool(decode_model))
                if node.kind == "cronus":
                    bal = Balancer(profile_prefill(lo), profile_chunked(hi))
                    system = build_cronus(
                        cfg, lo, hi, balancer=bal, sched_policy=policy,
                        prefix_cache=cache, host_kv_blocks=host,
                        decode_offload=node.options.get("decode_offload",
                                                        False), **kw)
                elif node.kind == "disagg_lh":
                    system = build_disaggregated(cfg, lo, hi,
                                                 sched_policy=policy,
                                                 prefix_cache=cache,
                                                 host_kv_blocks=host, **kw)
                else:                                   # disagg_hl
                    system = build_disaggregated(cfg, hi, lo,
                                                 sched_policy=policy,
                                                 prefix_cache=cache,
                                                 host_kv_blocks=host, **kw)
                endpoints.append(system.endpoint(name))
            elif node.kind == "pp":
                hi_spec, lo_spec = (DEVICES[d] for d in node.devices)
                device = PipelineDeviceModel(hi_spec, lo_spec, cfg)
                eng = Engine(name, cfg,
                             EngineConfig(
                                 max_batched_tokens=max_batched_tokens,
                                 max_slots=max_slots, block_size=block_size,
                                 num_kv_blocks=pool(device),
                                 sched_policy=policy, prefix_cache=cache,
                                 host_kv_blocks=host_tier(node, cache,
                                                          pool(device)),
                                 executor=executor),
                             device, executor_factory("pp"))
                endpoints.append(WorkerEndpoint(name, eng, queue_cap=None))
            else:                                        # worker
                dev = DeviceModel(DEVICES[node.devices[0]], cfg)
                eng = Engine(name, cfg,
                             EngineConfig(
                                 max_batched_tokens=node.options.get(
                                     "max_batched_tokens", max_batched_tokens),
                                 max_slots=max_slots, block_size=block_size,
                                 num_kv_blocks=pool(dev),
                                 sched_policy=policy, prefix_cache=cache,
                                 host_kv_blocks=host_tier(node, cache,
                                                          pool(dev)),
                                 executor=executor),
                             dev, executor_factory("worker"))
                endpoints.append(WorkerEndpoint(
                    name, eng,
                    queue_cap=node.options.get("queue_cap",
                                               worker_queue_cap)))

    if router is None:
        router = spec.router
    if isinstance(router, str):
        router = make_router(router)
    return ClusterSystem(endpoints=endpoints, router=router)
