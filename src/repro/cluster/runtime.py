"""Event-driven cluster runtime (xoscar-style actor loop, single process).

Before this module, each system class (Cronus, DP, PP) carried a private
copy of the same discrete-event loop: dispatch arrivals, move KV handoffs,
advance the lagging engine, jump clocks when idle. ``ClusterRuntime``
is that loop, written once, over an arbitrary set of *endpoints*:

  * an :class:`Endpoint` is a routable unit that accepts requests — a
    standalone chunked-prefill worker (:class:`WorkerEndpoint`) or a Cronus
    PPI+CPI pair (``repro.cluster.pair.CronusPairEndpoint``);
  * engines register with the runtime through their endpoint's ``engines``
    tuple and are advanced lagging-first (the engine with the smallest
    local clock that can make progress steps next — the same rule the
    per-system loops used, now global across the whole cluster);
  * timed events (KV-transfer completions posted by endpoints via
    :meth:`ClusterRuntime.post`) are kept in a heap and delivered eagerly
    in (time, seq) order — eager because engine admission gates on each
    request's ``ready_time``, so delivery order is deterministic and
    execution can never start before the event's timestamp.

Request timing is enforced by the engines themselves (``arrival`` /
``ready_time`` gate admission), so delivering a routed request into an
engine's queue "early" never lets it run early — which is what makes this
single loop bit-compatible with the three loops it replaced.
"""
from __future__ import annotations

import abc
import dataclasses
import heapq
import itertools
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.engine import Engine
from repro.core.metrics import aggregate
from repro.core.request import ReqState, Request
from repro.kvcache.transfer import TransferEngine


@dataclasses.dataclass(frozen=True)
class EndpointStats:
    """Load snapshot the routers and the autoscaler read.

    ``busy_frac`` is the max over the endpoint's engines of the fraction
    of the trailing ``Engine.BUSY_WINDOW`` simulated seconds spent
    executing iterations (max, not mean: a pair whose CPI is saturated is
    busy no matter how idle its PPI runs — scale-down must wait for both).
    ``oldest_queued_age`` is how long the oldest still-queued request has
    waited since its arrival — the leading signal that the endpoint mix
    is underprovisioned, visible long before goodput degrades."""
    queue_depth: int        # queued + resident, not yet finished
    free_kv_blocks: int     # free blocks on the endpoint's decode engine
    clock: float            # max engine clock (how far this endpoint has run)
    busy_frac: float = 0.0          # utilization over the trailing window
    oldest_queued_age: float = 0.0  # seconds the oldest queued request waited


class Endpoint(abc.ABC):
    """A routable unit of the cluster: one or more engines + local policy."""

    name: str

    @property
    @abc.abstractmethod
    def engines(self) -> Tuple[Engine, ...]:
        """Engines this endpoint registers with the runtime (order = tie
        order for lagging-first advancement)."""

    @abc.abstractmethod
    def can_accept(self, req: Request) -> bool:
        """May the router hand this request over right now?"""

    @abc.abstractmethod
    def submit(self, req: Request, runtime: Optional["ClusterRuntime"] = None):
        """Take ownership of a routed request."""

    def pump(self, runtime: Optional["ClusterRuntime"] = None):
        """Move internal handoffs (e.g. PPI->CPI KV transfers). Default: none."""

    def cancel(self, req: Request) -> bool:
        """Abort a routed request mid-flight: free its slot/KV blocks and
        record the cancelled terminal state. True if an engine held it."""
        for e in self.engines:
            if e.cancel(req.req_id) is not None:
                return True
        return False

    @abc.abstractmethod
    def finished(self) -> List[Request]:
        """Requests that completed on this endpoint."""

    def n_finished(self) -> int:
        """Completion count — hot path; override to avoid list copies."""
        return len(self.finished())

    def cached_prefix_tokens(self, req: Request) -> int:
        """Longest prefix of ``req``'s prompt resident in any of this
        endpoint's KV caches (0 when prefix caching is off) — the
        prefix-affinity routing signal. Read-only probe."""
        return max(e.allocator.lookup_prefix(req.prompt)
                   for e in self.engines)

    @property
    def sched_policy(self) -> str:
        """Batch-composition policy of the decode-side engine (pairs put
        the decode engine last in ``engines``) — where dynamic-KV growth
        and preemption happen, so it's the policy routers/operators care
        about when endpoints differ."""
        return self.engines[-1].ecfg.sched_policy

    def stats(self) -> EndpointStats:
        """Live load/capacity snapshot the routers and autoscaler read."""
        engines = self.engines
        queued = sum(len(e.queue) for e in engines) + sum(
            1 for e in engines for r in e.slots if r is not None)
        decode = engines[-1]   # pairs put the decode engine last
        clock = max(e.clock for e in engines)
        arrivals = [r.arrival for e in engines for r in e.queue]
        return EndpointStats(
            queue_depth=queued,
            free_kv_blocks=decode.stats().free_kv_blocks,
            clock=clock,
            busy_frac=max(e.busy_fraction() for e in engines),
            oldest_queued_age=(max(clock - min(arrivals), 0.0)
                               if arrivals else 0.0),
        )

    def drain(self) -> List[Request]:
        """Evict every resident and queued request for recompute elsewhere
        (endpoint detach). Residents leave via preemption-by-recompute —
        generated tokens folded into the prompt, KV freed — and everything
        queued is stripped of engine-local state, because any KV or
        payload it references lives on the hardware being removed. Returns
        the displaced requests (``finished()`` is untouched); afterwards
        the endpoint holds no work and allocator invariants are clean."""
        return [r for e in self.engines for r in e.drain_requests()]

    def migrate(self) -> List[Request]:
        """Evict every resident and queued request, carrying its computed
        KV *as a payload* instead of discarding it (detach with
        ``migrate=True``). Displaced requests re-enter the pending queue
        as KV-carrying migrants; the dispatcher ships each one through
        the cluster :class:`~repro.kvcache.TransferEngine` to an endpoint
        that ``accepts_kv`` it, falling back to recompute when none does.
        Requests with nothing extractable (still queued, or mid-transfer
        with no local KV) degrade to the same strip ``drain`` applies."""
        return [r for e in self.engines for r in e.migrate_requests()]

    def accepts_kv(self, req: Request) -> bool:
        """May a KV-carrying migrant be shipped here right now? Default
        False: only endpoints that know how to ingest a foreign payload
        opt in."""
        return False

    def submit_kv(self, req: Request,
                  runtime: Optional["ClusterRuntime"] = None):
        """Take ownership of a migrated KV-carrying request *without*
        resetting its ``ready_time`` (the transfer engine already gated
        delivery on it)."""
        raise NotImplementedError(
            f"endpoint {self.name!r} does not ingest migrated KV")


class WorkerEndpoint(Endpoint):
    """A standalone chunked-prefill+decode instance (DP worker, or the
    single fused engine of the PP baseline).

    ``queue_cap`` bounds the *waiting queue* only (paper §5.1's DP caps);
    ``None`` means unbounded (PP: everything funnels into one engine).
    """

    def __init__(self, name: str, engine: Engine,
                 queue_cap: Optional[int] = None):
        self.name = name
        self.engine = engine
        self.queue_cap = queue_cap

    @property
    def engines(self) -> Tuple[Engine, ...]:
        """The single wrapped engine."""
        return (self.engine,)

    def can_accept(self, req: Request) -> bool:
        """Whether the engine's queue has room (``queue_cap=None``: always)."""
        if self.queue_cap is None:
            return True
        return len(self.engine.queue) < self.queue_cap

    def submit(self, req: Request, runtime=None):
        """Queue a routed request on the engine (ready at its arrival)."""
        req.ready_time = req.arrival
        self.engine.add_request(req)

    def accepts_kv(self, req: Request) -> bool:
        """Whether this worker will ingest a migrated request's KV."""
        # a chunked worker can resume any migrant: ingest places it
        # straight into decode when the payload covers the prompt, or
        # continues the partial prefill otherwise
        return self.can_accept(req)

    def submit_kv(self, req: Request, runtime=None):
        """Ingest a migrated request, KV payload and all."""
        # deliberately NOT resetting ready_time: the migration transfer
        # gated delivery on it, and the payload's KV is only valid from
        # the moment the source finished extracting it
        self.engine.add_request(req)

    def finished(self) -> List[Request]:
        """Requests this endpoint completed."""
        return list(self.engine.finished)

    def n_finished(self) -> int:
        """Count of completed requests."""
        return len(self.engine.finished)


@dataclasses.dataclass(order=True)
class _Event:
    time: float
    seq: int
    fn: Callable[[], None] = dataclasses.field(compare=False)


class ClusterRuntime:
    """The shared event loop. One instance per ``run()`` of a trace."""

    def __init__(self, endpoints: Sequence[Endpoint], router):
        self.endpoints = list(endpoints)
        self.router = router
        # flight recorder (repro.obs): set by InferenceService.start_trace;
        # None = zero tracing overhead anywhere in the loop
        self.tracer = None
        self.engines: List[Engine] = [e for ep in self.endpoints
                                      for e in ep.engines]
        self._events: List[_Event] = []
        self._seq = itertools.count()
        # completions that outlive their endpoint: detach_endpoint moves
        # the departing endpoint's finished requests here so fleet metrics
        # and the n_finished termination condition never lose them
        self.retired: List[Request] = []
        self._draining: set = set()   # endpoint names closed to routing
        # per-endpoint dispatch tally (routed submits + KV deliveries),
        # surfaced by the opt-in utilization breakdown; survives detach so
        # a departed endpoint's share of the load stays attributed
        self.dispatched: Dict[str, int] = {}
        # every cross-pool KV move (PPI->CPI handoff, detach migration,
        # prefix fetch) goes through the one cluster transfer engine
        self.transfers = TransferEngine(self)
        for ep in self.endpoints:
            self.transfers.register(ep)
        if hasattr(router, "bind_runtime"):
            router.bind_runtime(self)

    # ------------------------------------------------------------------
    # timed events
    # ------------------------------------------------------------------
    def post(self, time: float, fn: Callable[[], None]):
        """Schedule ``fn`` at simulated time ``time`` (KV-transfer
        completions, deferred re-injections, ...)."""
        heapq.heappush(self._events, _Event(time, next(self._seq), fn))

    def _drain_events(self):
        # Delivery is EAGER: a routed request can't execute before its
        # ready_time anyway (engine admission gates on it), so holding an
        # event back until clocks reach its timestamp would only delay the
        # receiving queue, not change timing. The heap's job is to fire
        # simultaneous deliveries in deterministic (time, seq) order.
        while self._events:
            heapq.heappop(self._events).fn()

    # ------------------------------------------------------------------
    # live membership (elastic autoscaling)
    # ------------------------------------------------------------------
    def attach_endpoint(self, ep: Endpoint, now: Optional[float] = None):
        """Add ``ep`` to the live cluster. Its engines' clocks are pulled
        forward to ``now`` (default: the cluster's current max clock) so a
        freshly attached endpoint can never execute in the simulated past,
        and the router is told membership changed."""
        if any(e.name == ep.name for e in self.endpoints):
            raise ValueError(f"duplicate endpoint name {ep.name!r}")
        if now is None:
            now = max((e.clock for e in self.engines), default=0.0)
        for eng in ep.engines:
            eng.clock = max(eng.clock, now)
            eng.busy_since = eng.clock
        self.endpoints.append(ep)
        self.engines = [e for ep_ in self.endpoints for e in ep_.engines]
        self.transfers.register(ep)
        if self.tracer is not None:
            self.tracer.instant(self.tracer.control, "attach", now,
                                {"endpoint": ep.name}, cat="membership")
        self.router.on_membership_change(self.endpoints)

    def detach_endpoint(self, name: str,
                        pending: Optional[deque] = None,
                        migrate: bool = False) -> Endpoint:
        """Remove endpoint ``name`` from the live cluster, losing no work:
        the endpoint is first marked unroutable, its residents are drained
        — via the preemption-by-recompute path by default, or carrying
        their computed KV as migration payloads when ``migrate=True`` —
        the displaced requests are requeued into ``pending`` for
        re-routing, its finished requests are retired into fleet metrics,
        and only then are its engines removed from the event loop — with
        every allocator's ``check_invariants`` verified clean. Call
        between ticks (posted events are always drained within a tick).

        With ``migrate=True`` the dispatcher ships each KV-carrying
        migrant through :attr:`transfers` to an endpoint that
        ``accepts_kv`` it; migrants nobody accepts fall back to
        recompute, so migration is never worse than drain."""
        for ep in self.endpoints:
            if ep.name == name:
                break
        else:
            raise KeyError(f"unknown endpoint {name!r}; have "
                           f"{[e.name for e in self.endpoints]}")
        self._draining.add(name)
        if self.tracer is not None:
            self.tracer.instant(
                self.tracer.control, "detach",
                max((e.clock for e in self.engines), default=0.0),
                {"endpoint": name, "migrate": migrate}, cat="membership")
        try:
            displaced = ep.migrate() if migrate else ep.drain()
            for r in displaced:
                r.kv_src = name    # transfer-accounting source tag
            if displaced and pending is None:
                raise RuntimeError(
                    f"endpoint {name!r} holds {len(displaced)} unfinished "
                    "request(s) but no pending queue was given to requeue "
                    "them into")
            if pending is not None:
                # stable re-insertion keeps pending sorted by arrival (the
                # dispatch discipline run()'s up-front sort establishes);
                # displaced arrivals are in the past, so they re-route
                # ahead of future traffic
                for r in sorted(displaced, key=lambda r: r.arrival):
                    i = len(pending)
                    while i > 0 and pending[i - 1].arrival > r.arrival:
                        i -= 1
                    pending.insert(i, r)
            self.retired.extend(ep.finished())
            for eng in ep.engines:
                assert not eng.queue and all(s is None for s in eng.slots), \
                    f"drain left work on engine {eng.name!r}"
                eng.allocator.check_invariants()
            self.endpoints.remove(ep)
            self.engines = [e for ep_ in self.endpoints
                            for e in ep_.engines]
            self.transfers.deregister(name)
            self.router.on_membership_change(self.endpoints)
        finally:
            self._draining.discard(name)
        return ep

    # ------------------------------------------------------------------
    def n_finished(self) -> int:
        """Completions fleet-wide, including detached endpoints' retirees."""
        return sum(ep.n_finished() for ep in self.endpoints) \
            + len(self.retired)

    def _dispatch(self, pending: deque):
        """Route pending arrivals in head-of-line order (the discipline of
        the per-system loops this replaced). Routers that defer the head
        for placement reasons of their own (session stickiness) may opt
        into a bounded ``lookahead`` window so one pinned request doesn't
        convoy the unrelated traffic queued behind it. Endpoints mid-drain
        (``detach_endpoint``) are withheld from the router entirely."""
        endpoints = self.endpoints
        if self._draining:
            endpoints = [ep for ep in endpoints
                         if ep.name not in self._draining]
            if not endpoints:
                return
        while pending:
            head = pending[0]
            if head.kv_payload is not None and not head.local_payload \
                    and head.slot is None:
                # detach-time migrant carrying extracted KV: ship it
                # through the transfer engine to an endpoint that can
                # ingest the payload; nobody willing -> recompute
                pending.popleft()
                if not self._route_kv(head, endpoints):
                    _strip_to_recompute(head)
                    pending.appendleft(head)   # re-route as a fresh job
                continue
            ep = self.router.select(pending[0], endpoints)
            if ep is not None:
                self._record_dispatch(ep.name)
                if self.tracer is not None:
                    self._trace_route(head, ep)
                ep.submit(pending.popleft(), self)
                continue
            window = getattr(self.router, "lookahead", 0)
            placed_at = None
            for i, req in enumerate(pending):
                if i == 0:
                    continue
                if i > window:
                    break
                ep = self.router.select(req, endpoints)
                if ep is not None:
                    placed_at = i
                    break
            if placed_at is None:
                break   # nothing in the window can be placed right now
            req = pending[placed_at]
            del pending[placed_at]
            self._record_dispatch(ep.name)
            if self.tracer is not None:
                self._trace_route(req, ep, lookahead=placed_at)
            ep.submit(req, self)

    def _route_kv(self, req: Request, endpoints: List[Endpoint]) -> bool:
        """Ship a KV-carrying migrant to the least-loaded endpoint that
        will ingest it. The transfer engine schedules delivery at the
        migrant's ``ready_time`` (when extraction finished on the source)
        and the receiving engine charges the wire cost at ingest, exactly
        like a Cronus handoff. False when no endpoint accepts — the
        caller strips the payload and falls back to recompute routing."""
        acceptors = [ep for ep in endpoints if ep.accepts_kv(req)]
        if not acceptors:
            return False
        stats = [(ep.stats(), i, ep) for i, ep in enumerate(acceptors)]
        _, _, dst = min(stats,
                        key=lambda t: (t[0].queue_depth,
                                       -t[0].free_kv_blocks, t[1]))
        self._record_dispatch(dst.name)
        if self.tracer is not None:
            self.tracer.instant(
                self.tracer.control, "route_kv", req.ready_time,
                {"req": req.req_id, "endpoint": dst.name,
                 "src": req.kv_src or "detached",
                 "tokens": req.context_len})
        self.transfers.transfer(
            req, src=req.kv_src or "detached", dst=dst.name,
            deliver=lambda r, e=dst: e.submit_kv(r, self),
            when=req.ready_time, kind="migration")
        return True

    def _record_dispatch(self, name: str) -> None:
        self.dispatched[name] = self.dispatched.get(name, 0) + 1

    def _trace_route(self, req: Request, ep: Endpoint,
                     lookahead: int = 0) -> None:
        """Route-decision instant on the control track (tracing on only):
        which endpoint won the request, under which router, at what load
        (the router's selection signal)."""
        s = ep.stats()
        args = {"req": req.req_id, "endpoint": ep.name,
                "router": type(self.router).__name__,
                "queue_depth": s.queue_depth,
                "free_kv_blocks": s.free_kv_blocks}
        if lookahead:
            args["lookahead"] = lookahead
        self.tracer.instant(self.tracer.control, "route", req.arrival, args)

    def tick(self, pending: deque) -> bool:
        """One round of the event loop: dispatch pending arrivals, move
        internal handoffs, then advance the globally-lagging runnable
        engine (or, if the whole cluster is idle, jump every clock to the
        next event time). Returns False only when no progress is possible
        at all — the online facade (``repro.serving.api``) drives this
        incrementally; ``run`` below is the batch replay over it."""
        self._dispatch(pending)

        # ---- internal handoffs; fire what they posted --------------
        for ep in self.endpoints:
            ep.pump(self)
        self._drain_events()

        # ---- advance the globally-lagging runnable engine ----------
        for eng in sorted(self.engines, key=lambda e: e.clock):
            if eng.runnable():
                eng.step()
                return True
        # cluster idle: jump every clock to the next event time
        # (pump deliveries drained above, so only engine ready
        # times and undispatched arrivals remain)
        nexts = [t for e in self.engines
                 if (t := e.next_ready_time()) is not None]
        if pending:
            nexts.append(pending[0].arrival)
        # a candidate no clock sits below advances nothing: a past-arrival
        # pending head that dispatch just refused (admission caps — e.g.
        # work displaced by a detach) must not pin the jump to a no-op
        nexts = [t for t in nexts if any(t > e.clock for e in self.engines)]
        if not nexts:
            return False   # nothing can advance: honest stall
        t = min(nexts)
        for e in self.engines:
            e.clock = max(e.clock, t)
        return True

    def next_time(self, pending: Optional[deque] = None) -> Optional[float]:
        """Earliest simulated time at which the cluster can make progress
        (runnable engine clock, queued ready time, posted event, or the
        head pending arrival). None when fully idle."""
        cands = [e.clock for e in self.engines if e.runnable()]
        cands += [t for e in self.engines
                  if (t := e.next_ready_time()) is not None]
        if self._events:
            cands.append(self._events[0].time)
        if pending:
            cands.append(pending[0].arrival)
        return min(cands) if cands else None

    def next_action_time(self, pending: Optional[deque] = None
                         ) -> Optional[float]:
        """Simulated time of the next *executed* action: the lagging
        runnable engine's clock (what ``tick`` will step), or — only when
        nothing is runnable — the idle-jump target ``next_time`` reports.
        The open-loop driver gates live submissions on this rather than
        ``next_time``: a queued ready-time can be earlier than every
        runnable clock, and stopping on it would let an iteration *at or
        past* the submission instant run before the request exists."""
        run = [e.clock for e in self.engines if e.runnable()]
        if run:
            return min(run)
        return self.next_time(pending)

    def run(self, requests: List[Request], max_steps: int = 10_000_000):
        """Replay a trace over the cluster; returns aggregate metrics."""
        check_requests_fresh(requests)
        pending = deque(sorted(requests, key=lambda r: r.arrival))
        total = len(requests)
        steps = 0
        while self.n_finished() < total and steps < max_steps:
            steps += 1
            if not self.tick(pending):
                break
        return aggregate([r.metrics for ep in self.endpoints
                          for r in ep.finished()]
                         + [r.metrics for r in self.retired])


def _strip_to_recompute(r: Request) -> None:
    """Turn an unplaceable KV migrant back into a recompute job: fold its
    generated tokens into the prompt (the preemption discipline — they
    are committed output, replayed as context) and drop every payload
    field, so normal routing sees a fresh-looking request."""
    if r.generated:
        r.prompt = np.concatenate(
            [r.prompt, np.asarray(r.generated, np.int32)])
        r.output_len -= len(r.generated)
        r.generated = []
        r.preempted = True
    r.kv_payload = None
    r.first_token = None
    r.local_payload = False
    r.partial_len = 0
    r.context_len = 0
    r.kv_src = None
    r.state = ReqState.WAITING
    r.ready_time = r.arrival


def check_requests_fresh(requests: Sequence[Request]) -> None:
    """Engines mutate requests in place (state, generated tokens, metrics),
    so replaying the same ``Request`` objects twice silently corrupts the
    second run. Refuse loudly instead — callers re-using a trace should
    pass fresh copies (``Trace.fresh()`` / ``copy.deepcopy``)."""
    for r in requests:
        if (r.state is not ReqState.WAITING or r.generated
                or r.slot is not None or r.context_len != 0
                or r.metrics.first_token_time is not None
                or r.metrics.finish_time is not None
                or r.metrics.cancelled):
            raise ValueError(
                f"request {r.req_id!r} was already replayed through a "
                "system (engines mutate requests in place); pass fresh "
                "copies — Trace.fresh() or copy.deepcopy the trace")
