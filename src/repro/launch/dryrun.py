import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) and emit
memory/cost/roofline analyses. MUST run as its own process (the XLA_FLAGS
above lock the host device count at first jax init).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b \
      --shape decode_32k [--multi-pod] [--out out.json]
"""

import argparse          # noqa: E402
import json              # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402

import jax               # noqa: E402

from repro.configs import get_config, get_shape   # noqa: E402
from repro.launch.mesh import make_production_mesh, sharding_rules  # noqa: E402
from repro.launch.roofline import model_flops, roofline_report      # noqa: E402
from repro.launch.specs import make_serve_specs, make_train_specs   # noqa: E402
from repro.models import sharding as sharding_mod                    # noqa: E402


def _compile(cfg, shape, mesh, multi_pod, scan_unroll=False):
    if shape.kind == "train":
        step, specs = make_train_specs(cfg, shape, mesh, multi_pod=multi_pod,
                                       scan_unroll=scan_unroll)
        donate = (0, 1)
    else:
        step, specs = make_serve_specs(cfg, shape, mesh, multi_pod=multi_pod,
                                       scan_unroll=scan_unroll)
        donate = (1,)
    with mesh:
        lowered = jax.jit(step, donate_argnums=donate).lower(*specs)
        compiled = lowered.compile()
    return compiled


def _calibration_points(cfg):
    """Reduced-depth configs for the affine cost model total(L) = base +
    L*per_layer. XLA's cost analysis counts while-loop bodies once, so the
    calibration compiles run with the layer scan fully UNROLLED at tiny
    depth and extrapolate (verified: unrolled-L sweep is affine in L and
    matches straight-line code exactly)."""
    import dataclasses as dc
    if cfg.enc_dec:
        # vary decoder and encoder depth independently
        return [
            ("f11", dc.replace(cfg, n_layers=1, n_enc_layers=1)),
            ("f21", dc.replace(cfg, n_layers=2, n_enc_layers=1)),
            ("f12", dc.replace(cfg, n_layers=1, n_enc_layers=2)),
        ]
    if cfg.is_moe and cfg.moe_dense_layers:
        return [
            ("fa", dc.replace(cfg, n_layers=cfg.moe_dense_layers + 1)),
            ("fb", dc.replace(cfg, n_layers=cfg.moe_dense_layers + 2)),
        ]
    return [("fa", dc.replace(cfg, n_layers=1)),
            ("fb", dc.replace(cfg, n_layers=2))]


def _counts(compiled):
    from repro.launch.roofline import collective_bytes
    ca = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": float(coll["total"])}


def calibrated_counts(cfg, shape, mesh, multi_pod) -> dict:
    """Extrapolated per-device (flops, bytes, collective-bytes) for the full
    depth, from unrolled reduced-depth compiles."""
    pts = _calibration_points(cfg)
    counts = {}
    for name, c in pts:
        counts[name] = _counts(_compile(c, shape, mesh, multi_pod,
                                        scan_unroll=True))
    out = {}
    for key in ("flops", "bytes", "coll"):
        if cfg.enc_dec:
            f11, f21, f12 = (counts["f11"][key], counts["f21"][key],
                             counts["f12"][key])
            d_dec, d_enc = f21 - f11, f12 - f11
            out[key] = (f11 + (cfg.n_layers - 1) * d_dec
                        + (cfg.n_enc_layers - 1) * d_enc)
        else:
            a_l = pts[0][1].n_layers
            fa, fb = counts["fa"][key], counts["fb"][key]
            per_layer = fb - fa
            out[key] = fa + (cfg.n_layers - a_l) * per_layer
    return out


def run_one(arch: str, shape_name: str, multi_pod: bool,
            verbose: bool = True, calibrate: bool = True) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    sharding_mod.set_rules(sharding_rules(multi_pod, cfg), mesh)
    try:
        t0 = time.time()
        compiled = _compile(cfg, shape, mesh, multi_pod)
        t_compile = time.time() - t0

        report = roofline_report(compiled, n_chips, model_flops(cfg, shape))
        if calibrate:
            t1 = time.time()
            cal = calibrated_counts(cfg, shape, mesh, multi_pod)
            from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS
            report.update({
                "flops_per_device": cal["flops"],
                "flops_global": cal["flops"] * n_chips,
                "bytes_per_device": cal["bytes"],
                "collective_bytes_per_device": cal["coll"],
                "t_compute": cal["flops"] / PEAK_FLOPS,
                "t_memory": cal["bytes"] / HBM_BW,
                "t_collective": cal["coll"] / ICI_BW,
                "calibrated": True,
                "t_calibrate_s": round(time.time() - t1, 2),
            })
            terms = {"compute": report["t_compute"],
                     "memory": report["t_memory"],
                     "collective": report["t_collective"]}
            report["bottleneck"] = max(terms, key=terms.get)
            report["useful_flops_ratio"] = (
                report["model_flops_global"] / report["flops_global"]
                if report["flops_global"] else float("nan"))
        report.update({
            "arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "status": "ok",
            "t_compile_s": round(t_compile, 2),
        })
        if verbose:
            ma = compiled.memory_analysis()
            print(f"[{arch} x {shape_name} x {report['mesh']}] OK "
                  f"compile={t_compile:.1f}s "
                  f"calibrate={report.get('t_calibrate_s', 0)}s")
            print(f"  memory_analysis: {ma}")
            ca = compiled.cost_analysis()
            print(f"  cost_analysis: flops={ca.get('flops', 0):.3e} "
                  f"bytes={ca.get('bytes accessed', 0):.3e}")
            print(f"  roofline: compute={report['t_compute']*1e3:.3f}ms "
                  f"memory={report['t_memory']*1e3:.3f}ms "
                  f"collective={report['t_collective']*1e3:.3f}ms "
                  f"-> {report['bottleneck']}-bound "
                  f"useful_flops={report['useful_flops_ratio']:.3f}")
        return report
    finally:
        sharding_mod.set_rules(None)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    report = run_one(args.arch, args.shape, args.multi_pod)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2, default=str)
    return 0 if report["status"] == "ok" else 1


if __name__ == "__main__":
    sys.exit(main())
