"""Training launcher (CPU-scale functional training on reduced configs;
the full-scale distributed train_step is exercised via dryrun.py).

Example (trains a ~3M-param reduced llama for a few hundred steps):
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --steps 200
"""
from __future__ import annotations

import argparse

from repro.configs import get_config
from repro.models import build_model
from repro.training import AdamWConfig, Trainer, save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    model = build_model(cfg, exact_moe=True)
    trainer = Trainer(model,
                      AdamWConfig(lr=args.lr, warmup_steps=args.steps // 10,
                                  total_steps=args.steps),
                      batch_size=args.batch_size, seq_len=args.seq_len)
    params, opt = trainer.init()
    params, opt, losses = trainer.run(params, opt, args.steps, log_every=20)
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")
    if args.ckpt:
        save_checkpoint(args.ckpt, params, opt, args.steps)
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
