"""Abstract input specs (ShapeDtypeStruct + NamedSharding) per
(architecture x input shape) for the multi-pod dry-run. No allocation.

Sharding policy (see DESIGN.md §4):
  * batch        -> ('pod','data') / ('data',)
  * attention KV -> kv-heads on 'model' when divisible, else the cache
                    *sequence* on 'model' (GSPMD then computes flash-decode
                    style partial attention with all-reduce combines)
  * long_500k    -> batch=1: KV sequence sharded over data x model;
                    SWA-variant archs use a ring cache of window size
  * experts      -> 'model' (expert parallel), MoE dispatch via sort/gather
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.launch.mesh import batch_axes, sharding_rules
from repro.models import build_model
from repro.models.sharding import params_sharding_tree
from repro.training.optimizer import AdamWConfig, adamw_update, init_adamw

WHISPER_TRAIN_ENC_LEN = 1500


def _leaf_name(path) -> str:
    for k in reversed(path):
        key = getattr(k, "key", None)
        if isinstance(key, str):
            return key
    return ""


def _with_sharding(abstract, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        abstract, shardings)


def build_dryrun_model(cfg: ModelConfig, shape: InputShape,
                       scan_unroll: bool = False):
    """long_500k on full-attention archs uses the sliding-window variant."""
    window_override = None
    if shape.name == "long_500k" and cfg.swa_variant_window and not cfg.window_size:
        window_override = cfg.swa_variant_window
    return build_model(cfg, window_override=window_override,
                       scan_unroll=scan_unroll), window_override


def cache_seq_len(cfg: ModelConfig, shape: InputShape,
                  window_override) -> int:
    if cfg.arch_type == "ssm":
        return 1
    if window_override:
        # ring cache: window + chunk (decode chunk = 1). Must hold the full
        # window *plus* the tokens being written, or the write evicts
        # entries the chunk's own queries still need.
        return window_override + 1
    return shape.seq_len


def _cache_shardings(model, cfg, mesh, shape, s_kv, multi_pod,
                     hd_sharded: bool = False):
    b_ax = batch_axes(multi_pod) if shape.global_batch > 1 else None
    n_dev_model = mesh.shape["model"]
    kv_on_model = (cfg.n_kv_heads % n_dev_model == 0) and not cfg.mla_kv_lora_rank
    # decode with batch=1: shard sequence as much as possible
    if shape.global_batch == 1 and s_kv > 4096:
        seq_ax: object = ("data", "model")
        kv_on_model = False
    elif hd_sharded:
        # HC2-2: decode caches shard the HEAD DIM (or MLA latent rank); the
        # sequence stays unsharded so the 1-token .at[].set write stays a
        # cheap sharded in-place scatter. Attention contracts the sharded
        # dim -> one small all-reduce of scores/outputs per layer.
        seq_ax = None
        kv_on_model = False
    else:
        seq_ax = "model" if not kv_on_model else None

    def spec(path, leaf):
        name = _leaf_name(path)
        nd = len(leaf.shape)
        if name == "pos":
            return P(b_ax, seq_ax if not kv_on_model else None)
        if name in ("k", "v"):                     # [L,B,S,kv,hd]
            if hd_sharded:
                return P(None, b_ax, None, None, "model")
            return P(None, b_ax, seq_ax, "model" if kv_on_model else None, None)
        if name in ("ckv", "kpe"):                 # [L,B,S,r]
            if hd_sharded:
                return P(None, b_ax, None, "model")
            return P(None, b_ax, seq_ax, None)
        if name == "h":                            # [L,B,H,P,N]
            d_model_ok = leaf.shape[2] % n_dev_model == 0
            return P(None, b_ax, "model" if d_model_ok else None, None, None)
        if name == "conv":                         # [L,B,W-1,C]
            ok = leaf.shape[3] % n_dev_model == 0
            return P(None, b_ax, None, "model" if ok else None)
        if name in ("cross_k", "cross_v"):         # [L,B,S_enc,kv,hd]
            return P(None, b_ax, None, "model" if kv_on_model else None, None)
        return P(*([None] * nd))

    from repro.models.sharding import divisible_spec

    abstract = jax.eval_shape(
        functools.partial(model.init_cache, shape.global_batch, s_kv))
    return jax.tree_util.tree_map_with_path(
        lambda p, leaf: NamedSharding(
            mesh, divisible_spec(spec(p, leaf), leaf.shape, mesh)),
        abstract), abstract


def make_serve_specs(cfg: ModelConfig, shape: InputShape, mesh, *,
                     multi_pod: bool, scan_unroll: bool = False):
    """Returns (step_fn, arg_specs tuple) for prefill/decode shapes."""
    assert shape.kind in ("prefill", "decode")
    model, window_override = build_dryrun_model(cfg, shape, scan_unroll)
    rules = sharding_rules(multi_pod, cfg)
    seq_in_ax = rules.get("seq") if shape.kind == "prefill" else None
    b = shape.global_batch
    b_ax = batch_axes(multi_pod) if b > 1 else None
    s_q = 1 if shape.kind == "decode" else shape.seq_len
    s_kv = cache_seq_len(cfg, shape, window_override)

    # HC2-2 (§Perf, REFUTED): head-dim-sharded decode caches + true scatter
    # writes looked ideal on paper (O(1) write bytes, small score
    # all-reduces), but GSPMD cannot keep the hd-sharded scatter sharded
    # (the updates' post-reshape sharding is unrepresentable) and falls back
    # to all-gathering the cache: collective 2.8 ms -> 2664 ms on
    # deepseek-coder-33b decode_32k. Kept behind an env flag for the record;
    # default stays sequence-sharded + select writes.
    import os as _os
    n_model = mesh.shape["model"]
    hd_div = ((cfg.mla_kv_lora_rank % n_model == 0) if cfg.mla_kv_lora_rank
              else (cfg.head_dim % n_model == 0))
    hd_sharded = (_os.environ.get("REPRO_HD_SHARDED_DECODE") == "1"
                  and shape.kind == "decode" and b > 1
                  and s_kv == shape.seq_len and hd_div
                  and cfg.arch_type != "ssm")
    if hd_sharded:
        model = build_model(cfg, window_override=window_override,
                            scan_unroll=scan_unroll, decode_write="scatter")

    abstract_params = jax.eval_shape(
        lambda: model.init_params(jax.random.PRNGKey(0)))
    # serving deployment contract: weights shipped in bf16
    abstract_params = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if (s.dtype == jnp.float32 and len(s.shape) >= 2)
            else s.dtype),
        abstract_params)
    p_shard = params_sharding_tree(abstract_params, mesh, rules)
    params_spec = _with_sharding(abstract_params, p_shard)

    cache_shard, cache_abs = _cache_shardings(model, cfg, mesh, shape, s_kv,
                                              multi_pod,
                                              hd_sharded=hd_sharded)
    cache_spec = _with_sharding(cache_abs, cache_shard)

    # enc-dec (whisper): the *encoder* consumes stub embeddings; the decoder
    # (what prefill/decode shapes lower) takes token ids. Only decoder-only
    # embedding-input archs (VLM) feed embeddings at prefill.
    if cfg.embeddings_input and not cfg.enc_dec and shape.kind == "prefill":
        tok_spec = jax.ShapeDtypeStruct(
            (b, s_q, cfg.d_model), jnp.bfloat16,
            sharding=NamedSharding(mesh, P(b_ax, seq_in_ax, None)))
    else:
        tok_spec = jax.ShapeDtypeStruct(
            (b, s_q), jnp.int32,
            sharding=NamedSharding(mesh, P(b_ax, seq_in_ax)))
    len_spec = jax.ShapeDtypeStruct((b,), jnp.int32,
                                    sharding=NamedSharding(mesh, P(None)))
    kvpos_sharding = jax.tree.leaves(
        cache_shard, is_leaf=lambda x: isinstance(x, NamedSharding))[0]
    # kv_positions aligned with cache['pos'] sharding
    pos_shard = cache_shard["pos"]
    kvpos_spec = jax.ShapeDtypeStruct((b, max(s_kv, 1)), jnp.int32,
                                      sharding=pos_shard)

    decode = shape.kind == "decode"

    def step(params, cache, tokens, cache_len, kv_positions):
        logits, new_cache, _ = model.forward(
            params, tokens, cache, cache_len, kv_positions=kv_positions,
            decode=decode)
        return logits, new_cache

    return step, (params_spec, cache_spec, tok_spec, len_spec, kvpos_spec)


def make_train_specs(cfg: ModelConfig, shape: InputShape, mesh, *,
                     multi_pod: bool, scan_unroll: bool = False):
    assert shape.kind == "train"
    model = build_model(cfg, remat=True, scan_unroll=scan_unroll)
    rules = sharding_rules(multi_pod, cfg)
    b = shape.global_batch
    b_ax = batch_axes(multi_pod)

    abstract_params = jax.eval_shape(
        lambda: model.init_params(jax.random.PRNGKey(0)))
    p_shard = params_sharding_tree(abstract_params, mesh, rules)
    params_spec = _with_sharding(abstract_params, p_shard)

    abstract_opt = jax.eval_shape(lambda: init_adamw(abstract_params))
    opt_shard = {
        "step": NamedSharding(mesh, P()),
        "m": p_shard,
        "v": p_shard,
    }
    opt_spec = _with_sharding(abstract_opt, opt_shard)

    batch_spec = {
        "tokens": jax.ShapeDtypeStruct(
            (b, shape.seq_len + 1), jnp.int32,
            sharding=NamedSharding(mesh, P(b_ax, None))),
    }
    if cfg.enc_dec:
        batch_spec["enc_emb"] = jax.ShapeDtypeStruct(
            (b, WHISPER_TRAIN_ENC_LEN, cfg.d_model), jnp.bfloat16,
            sharding=NamedSharding(mesh, P(b_ax, None, None)))

    opt_cfg = AdamWConfig()

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state, _ = adamw_update(opt_cfg, params, grads, opt_state)
        return params, opt_state, loss

    return step, (params_spec, opt_spec, batch_spec)
