"""Dry-run sweep driver: subprocess per (arch x shape x mesh) — each run
needs a fresh process because XLA_FLAGS locks the host device count.

  PYTHONPATH=src python -m repro.launch.sweep --out results/dryrun \
      [--multi-pod] [--archs a,b] [--shapes x,y] [--no-calibrate]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import ARCH_IDS, INPUT_SHAPES

ASSIGNED = [a for a in ARCH_IDS if a not in ("llama3-8b", "qwen2-7b")]


def run_subprocess(arch: str, shape: str, multi_pod: bool, out_dir: str,
                   timeout: int = 3600) -> dict:
    tag = f"{arch}__{shape}__{'mp' if multi_pod else 'sp'}"
    out_file = os.path.join(out_dir, tag + ".json")
    if os.path.exists(out_file):
        with open(out_file) as f:
            return json.load(f)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", out_file]
    if multi_pod:
        cmd.append("--multi-pod")
    t0 = time.time()
    proc = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout,
                          env={**os.environ, "PYTHONPATH": "src"})
    if proc.returncode != 0 or not os.path.exists(out_file):
        err = proc.stderr.strip().splitlines()
        report = {"arch": arch, "shape": shape,
                  "mesh": "2x16x16" if multi_pod else "16x16",
                  "status": "fail", "wall_s": round(time.time() - t0, 1),
                  "error": err[-3:] if err else ["unknown"]}
        with open(out_file, "w") as f:
            json.dump(report, f, indent=2)
        return report
    with open(out_file) as f:
        return json.load(f)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--archs", default=",".join(ASSIGNED))
    ap.add_argument("--shapes", default=",".join(INPUT_SHAPES))
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for arch in args.archs.split(","):
        for shape in args.shapes.split(","):
            for mp in meshes:
                t0 = time.time()
                r = run_subprocess(arch, shape, mp, args.out)
                status = r.get("status")
                bn = r.get("bottleneck", "-")
                print(f"{arch:22s} {shape:12s} {'2x16x16' if mp else '16x16':8s}"
                      f" {status:4s} [{time.time()-t0:5.0f}s] bound={bn}",
                      flush=True)
                results.append(r)
    n_ok = sum(1 for r in results if r.get("status") == "ok")
    print(f"\n{n_ok}/{len(results)} OK")
    with open(os.path.join(args.out, "summary.json"), "w") as f:
        json.dump(results, f, indent=2, default=str)


if __name__ == "__main__":
    main()
