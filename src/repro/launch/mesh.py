"""Production meshes.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods x 256 as (pod=2, data=16, model=16) — the `pod` axis is
the Cronus instance boundary (pod 0 = CPI slice, pod 1 = PPI slice; see
DESIGN.md §3), and also the DCN data-parallel axis for training shapes.

Defined as functions (not module constants) so importing never touches jax
device state; the dry-run sets XLA_FLAGS before any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def sharding_rules(multi_pod: bool, cfg=None) -> dict:
    """Logical-axis -> mesh-axis mapping used by the models' activation
    constraints and the name-based parameter specs.

    Small-model policy (§Perf HC3): when d_model is small (whisper-base:
    512), 16-way tensor parallelism makes every layer's activation
    all-reduce dominate (measured: whisper prefill_32k collective 1038 ms vs
    11.5 ms compute). Such models REPLICATE weights (they fit per chip many
    times over) and keep only batch sharding — the model axis then acts as
    extra batch parallelism via GSPMD's divisibility-aware batch split."""
    rules = {
        "batch": batch_axes(multi_pod),
        "model": "model",
        "heads": "model",
        "ff": "model",
        "experts": "model",
        "vocab": "model",
        "kv_seq": "model",
    }
    rules["seq"] = None
    if cfg is not None and cfg.d_model < 2048 and not cfg.is_moe \
            and cfg.arch_type not in ("ssm", "hybrid"):
        rules.update({"model": None, "heads": None, "ff": None,
                      "vocab": None, "seq": "model"})
    return rules
