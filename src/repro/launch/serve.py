"""Serving launcher: run Cronus (or a baseline) on a trace — on a single
high/low pair (``--approach``) or on a whole heterogeneous cluster
(``--cluster``).

Examples:
  # paper-scale scheduling/timing run (null executor, simulated clocks):
  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b \
      --approach cronus --hi A100 --lo A10 --n-requests 1000

  # same pair under the sarathi multi-sequence chunk-packing scheduler
  # (lazy paged-KV growth + preemption-by-recompute on OOM):
  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b \
      --approach cronus --sched-policy sarathi --n-requests 1000

  # multi-instance cluster: two Cronus pairs + four A10 workers behind a
  # least-loaded router; per-endpoint policies via the @policy suffix
  # (workers run SJF, pairs keep the --sched-policy default):
  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b \
      --cluster "2xcronus:A100+A10,4xworker:A10@sjf" \
      --router least_loaded --n-requests 2000

  # shared-prefix workload with block-level KV reuse and prefix-affinity
  # routing (requests chase the endpoint already holding their prefix):
  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b \
      --cluster "4xworker:A10" --prefix-cache --router prefix_affinity \
      --trace shared_prefix --n-requests 1000

  # functional run with real JAX execution on reduced config:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --smoke \
      --approach cronus --n-requests 8 --real --scale 0.02
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.cluster import build_cluster
from repro.cluster.router import ROUTERS
from repro.configs import get_config
from repro.core.executor import NullExecutor, RealExecutor
from repro.models import build_model
from repro.scheduling import SCHEDULERS
from repro.serving.hardware import DEVICES
from repro.serving.simulator import APPROACHES, build_system
from repro.serving.trace import make_shared_prefix_trace, make_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--approach", default="cronus", choices=APPROACHES)
    ap.add_argument("--hi", default="A100", choices=sorted(DEVICES))
    ap.add_argument("--lo", default="A10", choices=sorted(DEVICES))
    ap.add_argument("--cluster", default=None,
                    help="cluster spec, e.g. '2xcronus:A100+A10,4xworker:A10'"
                         " (overrides --approach/--hi/--lo)")
    ap.add_argument("--router", default="least_loaded",
                    choices=sorted(ROUTERS), help="cluster request router")
    ap.add_argument("--sched-policy", default="fcfs",
                    choices=sorted(SCHEDULERS),
                    help="iteration-level batch-composition policy "
                         "(fcfs = seed-identical; sarathi/sjf pack multiple "
                         "prefills, grow KV lazily and preempt on OOM); "
                         "per-endpoint override via '@policy' in --cluster")
    ap.add_argument("--sessions", type=int, default=0,
                    help="tag requests with this many conversation ids "
                         "(session-affinity routing)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="shared-prefix KV reuse (refcounted copy-on-write "
                         "block cache); per-endpoint override via '@cache' "
                         "in --cluster. Simulation-only: not valid with "
                         "--real, whose slot cache holds no cached prefix")
    ap.add_argument("--trace", default="azure",
                    choices=("azure", "shared_prefix"),
                    help="workload shape: the Azure-conversation trace, or "
                         "the multi-tenant shared-prefix trace where "
                         "--prefix-cache pays off")
    ap.add_argument("--prefix-groups", type=int, default=8,
                    help="shared_prefix trace: number of distinct prefixes")
    ap.add_argument("--prefix-len", type=int, default=512,
                    help="shared_prefix trace: tokens per shared prefix")
    ap.add_argument("--n-requests", type=int, default=1000)
    ap.add_argument("--interval", type=float, default=0.0,
                    help="arrival interval (s); 0 = all at t0 (max tput)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config")
    ap.add_argument("--real", action="store_true",
                    help="real JAX execution (requires --smoke scale)")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="trace length scale (use ~0.02 with --real)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.trace == "shared_prefix":
        reqs = make_shared_prefix_trace(
            args.n_requests, seed=args.seed, interval=args.interval,
            n_prefixes=args.prefix_groups, prefix_len=args.prefix_len,
            vocab_size=cfg.vocab_size, scale=args.scale)
    else:
        reqs = make_trace(args.n_requests, seed=args.seed,
                          interval=args.interval, vocab_size=cfg.vocab_size,
                          scale=args.scale, sessions=args.sessions or None)
    if args.real and (args.prefix_cache or "@cache" in (args.cluster or "")):
        raise SystemExit("prefix caching (--prefix-cache / '@cache' node "
                         "suffix) models KV reuse at the block-table level; "
                         "the RealExecutor's slot cache cannot serve cached "
                         "prefixes, so it is simulation-only")

    if args.real:
        model = build_model(cfg, exact_moe=True)
        params = model.init_params(jax.random.PRNGKey(0))
        s_kv = int(max(r.input_len + r.output_len for r in reqs) + 8)

        def factory(role):
            return RealExecutor(model, params,
                                max_slots=2 if role == "ppi" else 16,
                                s_kv=s_kv)
        ex_kw = dict(executor_factory=factory, max_slots=16, block_size=4)
    else:
        ex_kw = dict(executor_factory=lambda role: NullExecutor())

    if args.cluster:
        system = build_cluster(cfg, args.cluster, router=args.router,
                               sched_policy=args.sched_policy,
                               prefix_cache=args.prefix_cache, **ex_kw)
    else:
        system = build_system(args.approach, cfg, DEVICES[args.hi],
                              DEVICES[args.lo],
                              sched_policy=args.sched_policy,
                              prefix_cache=args.prefix_cache, **ex_kw)
    metrics = system.run(reqs)
    print(json.dumps(metrics, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(metrics, f, indent=2)


if __name__ == "__main__":
    main()
