"""Serving launcher over the online API: a :class:`~repro.serving.api.
ServeSpec` describes the system (pair or cluster, router, scheduler,
executor), a trace describes the workload, and the built
:class:`~repro.serving.api.InferenceService` replays it — batch
(``run``-equivalent submit-all + drain), streaming (``--stream``), or
with a mid-flight cancellation (``--cancel-after``).

Examples:
  # paper-scale scheduling/timing run (null executor, simulated clocks):
  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b \
      --approach cronus --hi A100 --lo A10 --n-requests 1000

  # same pair under the sarathi multi-sequence chunk-packing scheduler:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b \
      --approach cronus --sched-policy sarathi --n-requests 1000

  # multi-instance cluster behind a least-loaded router:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b \
      --cluster "2xcronus:A100+A10,4xworker:A10@sjf" \
      --router least_loaded --n-requests 2000

  # shared-prefix workload with KV reuse + prefix-affinity routing:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b \
      --cluster "4xworker:A10" --prefix-cache --router prefix_affinity \
      --trace shared_prefix --n-requests 1000

  # honest open-loop load: live submission at Poisson 6 QPS (reports the
  # queueing/service split of TTFT alongside the usual tails):
  PYTHONPATH=src python -m repro.launch.serve --approach cronus \
      --arrival poisson:6 --n-requests 1000

  # elastic autoscaling under a diurnal ramp: start with one pair, let
  # the SLO-driven autoscaler attach/detach from a 1xA100 + 4xA10 rack:
  PYTHONPATH=src python -m repro.launch.serve --approach cronus \
      --arrival ramp:2:8:120 --n-requests 600 \
      --autoscale "slo:goodput>=0.9:cooldown=10" --inventory "A100:1,A10:4"

  # stream the first request's tokens, cancel it after 32:
  PYTHONPATH=src python -m repro.launch.serve --approach cronus \
      --n-requests 50 --stream --cancel-after 32

  # persist / reuse a deployment description:
  PYTHONPATH=src python -m repro.launch.serve --sched-policy sarathi \
      --dump-spec sarathi.json
  PYTHONPATH=src python -m repro.launch.serve --spec sarathi.json \
      --n-requests 500

  # auto-topology planning: search the placements a rack supports for the
  # best SLO capacity per device-cost, print the ranked plan, then serve
  # the winner at its measured capacity:
  PYTHONPATH=src python -m repro.launch.serve --plan "A100:1,A10:2" \
      --workload "azure:poisson:n=40:ttft=2.0:tbt=0.1" --serve-best

  # functional run with real JAX execution on reduced config:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --smoke \
      --approach cronus --n-requests 8 --real --scale 0.02
"""
from __future__ import annotations

import argparse
import json

from repro.configs import get_config
from repro.serving.api import ServeSpec
from repro.serving.trace import make_shared_prefix_trace, make_trace
from repro.workloads import OpenLoopDriver


def build_arg_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    # ---- system description: every flag here mirrors a ServeSpec field
    ServeSpec.add_cli_args(ap)
    # ---- workload (the trace is not part of the deployment spec)
    w = ap.add_argument_group("workload")
    w.add_argument("--trace", default="azure",
                   choices=("azure", "shared_prefix"),
                   help="workload shape: the Azure-conversation trace, or "
                        "the multi-tenant shared-prefix trace where "
                        "--prefix-cache pays off")
    w.add_argument("--n-requests", type=int, default=1000)
    w.add_argument("--interval", type=float, default=0.0,
                   help="arrival interval (s); 0 = all at t0 (max tput)")
    w.add_argument("--seed", type=int, default=0)
    w.add_argument("--scale", type=float, default=1.0,
                   help="trace length scale (use ~0.02 with --real)")
    w.add_argument("--sessions", type=int, default=0,
                   help="tag requests with this many conversation ids "
                        "(session-affinity routing)")
    w.add_argument("--prefix-groups", type=int, default=8,
                   help="shared_prefix trace: number of distinct prefixes")
    w.add_argument("--prefix-len", type=int, default=512,
                   help="shared_prefix trace: tokens per shared prefix")
    # ---- auto-topology planner (repro.autotopo)
    p = ap.add_argument_group(
        "auto-topology planner",
        "search the rack's placement space with find_capacity probes")
    p.add_argument("--plan", default=None, metavar="RACK",
                   help="plan over this device inventory (e.g. "
                        "'A100:1,A10:2') instead of serving; prints the "
                        "ranked plan. --n-requests/--scale/--seed override "
                        "the probe workload when given")
    p.add_argument("--workload", default=None, metavar="SPEC",
                   help="workload to plan for: TRACE:ARRIVAL[:key=value...]"
                        ", e.g. 'azure:poisson:n=40:ttft=2.0:tbt=0.1' "
                        "(default azure:poisson; only valid with --plan)")
    p.add_argument("--serve-best", action="store_true",
                   help="after planning, serve the top candidate open-loop "
                        "at its measured capacity (ServeSpec.from_plan)")
    p.add_argument("--plan-beam", type=int, default=2, metavar="W",
                   help="beam width of the constructive search")
    p.add_argument("--plan-max-endpoints", type=int, default=4, metavar="N",
                   help="endpoint fan-out cap per layout")
    p.add_argument("--plan-memo", default=None, metavar="FILE",
                   help="evaluation-memo JSON: loaded if present, saved "
                        "after planning — a re-run re-probes nothing")
    p.add_argument("--plan-out", default=None, metavar="FILE",
                   help="write the full PlanResult as JSON")
    p.add_argument("--plan-top", type=int, default=5, metavar="K",
                   help="ranked rows to print")
    # ---- demo / IO
    d = ap.add_argument_group("online demo / output")
    d.add_argument("--stream", action="store_true",
                   help="print the first request's tokens as they arrive "
                        "(token id + simulated timestamp)")
    d.add_argument("--cancel-after", type=int, default=None, metavar="K",
                   help="cancel the first request mid-flight after K of "
                        "its tokens (its slot/KV blocks are freed; it is "
                        "reported under the 'cancelled' metric)")
    d.add_argument("--spec", default=None, metavar="FILE",
                   help="load the ServeSpec from a JSON file "
                        "(system flags on the command line are ignored)")
    d.add_argument("--dump-spec", default=None, metavar="FILE",
                   help="write the resolved ServeSpec as JSON and exit "
                        "('-' for stdout)")
    d.add_argument("--trace-out", default=None, metavar="FILE",
                   help="record the run's flight-recorder trace and "
                        "write Perfetto-loadable Chrome JSON here "
                        "(analyze with tools/trace_report.py; see "
                        "docs/OBSERVABILITY.md)")
    d.add_argument("--out", default=None)
    return ap


def _make_trace(args, spec: ServeSpec, vocab_size: int):
    if spec.arrival is not None and args.interval:
        raise SystemExit("bad workload: pass either --interval (closed-loop "
                         "fixed spacing) or --arrival (open-loop process), "
                         "not both")
    kw = dict(seed=args.seed, interval=args.interval, arrival=spec.arrival,
              vocab_size=vocab_size, scale=args.scale)
    if args.trace == "shared_prefix":
        return make_shared_prefix_trace(
            args.n_requests, n_prefixes=args.prefix_groups,
            prefix_len=args.prefix_len, **kw)
    return make_trace(args.n_requests, sessions=args.sessions or None, **kw)


def _run_plan(args):
    """The ``--plan`` mode: search, print, persist, optionally serve."""
    import dataclasses
    import os

    from repro.autotopo import EvalMemo, TopologyPlanner, parse_workload

    if args.spec:
        raise SystemExit("bad plan: --plan searches topologies itself; "
                         "it cannot be combined with a fixed --spec file")
    if args.autoscale or args.inventory:
        raise SystemExit("bad plan: --plan sizes a fixed fleet up front; "
                         "elastic --autoscale/--inventory is the other "
                         "answer to the same question — pick one")
    if args.stream or args.cancel_after is not None:
        raise SystemExit("bad plan: --stream/--cancel-after demo the "
                         "closed-loop replay path; planning (and "
                         "--serve-best) runs open-loop")
    if args.trace_out:
        raise SystemExit("bad plan: --trace-out records one serving run; "
                         "planning probes many candidate runs — trace the "
                         "winner by serving it directly")
    try:
        workload = parse_workload(args.workload or "azure:poisson")
        # the workload-group flags shrink probe traces when given
        # explicitly (how docs_smoke/CI quick-scale a documented plan)
        overrides = {}
        if args.n_requests != 1000:
            overrides["n_requests"] = args.n_requests
        if args.scale != 1.0:
            overrides["scale"] = args.scale
        if args.seed != 0:
            overrides["seed"] = args.seed
        if overrides:
            workload = dataclasses.replace(workload, **overrides)
        memo = (EvalMemo.load(args.plan_memo)
                if args.plan_memo and os.path.exists(args.plan_memo)
                else None)
        planner = TopologyPlanner(
            args.plan, workload, beam_width=args.plan_beam,
            max_endpoints=args.plan_max_endpoints, memo=memo)
        plan = planner.plan()
    except (ValueError, OSError, json.JSONDecodeError) as e:
        raise SystemExit(f"bad plan: {e}")
    print(plan.summary(args.plan_top))
    if args.plan_memo:
        planner.memo.save(args.plan_memo)
    if args.plan_out:
        with open(args.plan_out, "w") as f:
            json.dump(plan.to_dict(), f, indent=1)
    if not args.serve_best:
        return
    best = plan.best
    if best.capacity_qps <= 0:
        raise SystemExit("bad plan: no candidate sustained the SLO target "
                         "— nothing to --serve-best (relax the workload "
                         "SLOs or grow the rack)")
    from repro.serving.api import ServeSpec
    spec = ServeSpec.from_plan(plan)
    print(f"# serving {best.cluster} behind {best.router} at "
          f"{best.capacity_qps:.2f} qps ({spec.arrival})")
    driver = OpenLoopDriver(spec.build())
    driver.run(workload.make_requests(best.capacity_qps))
    metrics = driver.metrics(ttft_slo=workload.ttft_slo,
                             tbt_slo=workload.tbt_slo, utilization=True)
    print(json.dumps(metrics, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(metrics, f, indent=2)


def main():
    args = build_arg_parser().parse_args()
    if args.plan:
        return _run_plan(args)
    if args.serve_best or args.workload:
        raise SystemExit("bad plan: --serve-best/--workload describe the "
                         "planning mode; they need --plan RACK")
    try:
        spec = (ServeSpec.from_json_file(args.spec) if args.spec
                else ServeSpec.from_cli(args))
    except (ValueError, OSError, json.JSONDecodeError) as e:
        raise SystemExit(f"bad serving spec: {e}")

    if args.dump_spec:
        text = json.dumps(spec.to_dict(), indent=2)
        if args.dump_spec == "-":
            print(text)
        else:
            with open(args.dump_spec, "w") as f:
                f.write(text + "\n")
        return

    cfg = get_config(spec.arch, smoke=spec.smoke)
    reqs = _make_trace(args, spec, cfg.vocab_size)
    if spec.executor in ("real", "paged") and spec.s_kv is None:
        spec = spec.replace(s_kv=int(
            max(r.input_len + r.output_len for r in reqs) + 8))

    if spec.autoscale is not None and spec.arrival is None:
        # closed-loop replay submits the whole trace up-front, so the
        # autoscaler would see an epoch of queueing at t=0 and scale to
        # the rack limit immediately — not a load signal, an artifact
        raise SystemExit("bad workload: --autoscale reacts to live load; "
                         "drive it open-loop with --arrival "
                         "(e.g. --arrival ramp:2:8)")

    if spec.arrival is not None:
        # open-loop: live submission at each wall-time offset — the demo
        # flags follow a single handle through a pre-submitted batch, which
        # contradicts arrival-time submission, so they are refused
        if args.stream or args.cancel_after is not None:
            raise SystemExit("bad workload: --stream/--cancel-after demo the "
                             "closed-loop replay path; they cannot follow an "
                             "--arrival open-loop run")
        service = spec.build()
        if args.trace_out:
            service.start_trace()
        driver = OpenLoopDriver(service)
        driver.run(reqs)
        metrics = driver.metrics()
        scaler = driver.service.autoscaler
        if scaler is not None:
            metrics["autoscale"] = scaler.report(driver.service.now)
    else:
        service = spec.build()
        if args.trace_out:
            service.start_trace()
        handles = [service.submit(r) for r in reqs]

        if args.stream or args.cancel_after is not None:
            # online demo: follow the first request's token stream (this
            # advances the whole cluster), optionally cancelling mid-flight
            head = handles[0]
            for n, (tok, t) in enumerate(head.tokens(), start=1):
                if args.stream:
                    print(f"[{head.req_id} t={t:9.4f}s] token {n}/"
                          f"{head.request.output_len}: {tok}")
                if args.cancel_after is not None and n >= args.cancel_after:
                    head.cancel()
                    print(f"[{head.req_id}] cancelled after {n} tokens "
                          f"(status={head.status})")
                    break

        metrics = service.drain()
    if args.trace_out:
        service.export_trace(args.trace_out)
    print(json.dumps(metrics, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(metrics, f, indent=2)


if __name__ == "__main__":
    main()
