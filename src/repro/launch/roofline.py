"""Roofline-term extraction from the compiled dry-run artifact.

Terms (per EXPERIMENTS.md §Roofline, TPU v5e targets):
  compute    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)
  memory     = HLO_bytes / (chips x 819 GB/s)
  collective = collective_bytes / (chips x 50 GB/s per ICI link)

``cost_analysis`` operates on the SPMD-partitioned per-device module, so its
flops/bytes are per-device; we report both per-device and global (x chips).
Collective bytes are parsed from the optimized HLO text: the sum of result-
shape bytes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (per device).
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12      # bf16, per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|c64|c128)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dtype, 4)
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes (per device) from optimized HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        # result shape appears before " <op-name>(", e.g.:
        #   %ag = bf16[8,128]{1,0} all-gather(%x), ...
        for kind in _COLLECTIVES:
            marker = f" {kind}("
            if marker in ls and not ls.startswith("//"):
                # left side of '=' may also contain shapes (variable name no);
                # take the section between '=' and the op marker
                eq = ls.find("=")
                seg = ls[eq + 1: ls.find(marker)] if eq >= 0 else ls
                out[kind] += _shape_bytes(seg)
                out["count"] += 1
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def roofline_report(compiled, n_chips: int, model_flops_global: float) -> Dict:
    cost = compiled.cost_analysis() or {}
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    mem = compiled.memory_analysis()
    report = {
        "chips": n_chips,
        "flops_per_device": flops_dev,
        "flops_global": flops_dev * n_chips,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll["total"],
        "collective_ops": {k: coll[k] for k in _COLLECTIVES},
        "collective_count": coll["count"],
        "t_compute": flops_dev / PEAK_FLOPS,
        "t_memory": bytes_dev / HBM_BW,
        "t_collective": coll["total"] / ICI_BW,
        "model_flops_global": model_flops_global,
    }
    report["useful_flops_ratio"] = (
        model_flops_global / report["flops_global"]
        if report["flops_global"] else float("nan"))
    terms = {"compute": report["t_compute"], "memory": report["t_memory"],
             "collective": report["t_collective"]}
    report["bottleneck"] = max(terms, key=terms.get)
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes"):
        try:
            report[f"mem_{attr}"] = int(getattr(mem, attr))
        except Exception:
            pass
    return report


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N_active·D for training, 2·N_active·D for serving,
    D = total tokens processed globally this step."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch  # decode: one token per request
    return 2.0 * n * tokens
