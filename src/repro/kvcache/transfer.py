"""General KV transfer engine: every KV movement through one mechanism.

Mooncake/NIXL-style (SNIPPETS §1): endpoints register their pools with one
cluster-owned :class:`TransferEngine`; every cross-pool KV move — the
Cronus PPI→CPI handoff, detach-time cache migration, cross-endpoint prefix
fetch — is an async ``transfer`` that resolves into the shared event loop.
The engine is a *mechanism*, not a policy: callers decide what moves where;
it owns delivery scheduling, cancellation, cost accounting, and the
observability counters.

Two charge disciplines, matching how the simulation prices movement:

  * ``charge="ingest"`` — delivery fires at ``when`` and the *receiving*
    engine charges ``DeviceModel.transfer_time`` when it ingests the
    payload, overlapped with its compute (the paper's §4.2 steps 6-7;
    bit-identical to the pre-engine Cronus handoff path);
  * ``charge="link"`` — the link time is added to the request's
    ``ready_time`` up front (used for cross-endpoint prefix fetches,
    where no payload ingest follows on the destination).

Cancellation: a handle cancelled mid-flight (or a request reaching
``CANCELLED`` state before delivery) simply never delivers — the source
pool freed its blocks when the payload was extracted, the destination pool
never saw them, so both sides stay clean by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

from repro.core.request import ReqState, Request

CHARGE_MODES = ("ingest", "link")


@dataclasses.dataclass
class TransferHandle:
    """One in-flight (or settled) KV transfer."""

    req_id: str
    src: str                   # source pool name (engine/endpoint)
    dst: str                   # destination pool name
    n_tokens: int              # KV tokens crossing
    t_post: float              # simulated time the transfer was issued
    link_time: float           # modeled seconds on the wire
    kind: str = "handoff"      # handoff | migration | prefix_fetch
    state: str = "inflight"    # inflight | delivered | cancelled

    def cancel(self) -> bool:
        """Abort before delivery. True if the transfer was still in
        flight (the delivery event becomes a no-op)."""
        if self.state == "inflight":
            self.state = "cancelled"
            return True
        return False


class TransferEngine:
    """Cluster-wide KV movement: registered pools + async transfers.

    One instance per :class:`~repro.cluster.runtime.ClusterRuntime`; when
    constructed without a runtime (legacy single-system paths) deliveries
    fire synchronously, which preserves the old direct-call semantics.
    """

    def __init__(self, runtime=None):
        self._runtime = runtime
        self._pools: Dict[str, object] = {}       # name -> endpoint
        self._inflight: Dict[str, TransferHandle] = {}
        self.n_transfers = 0
        self.n_cancelled = 0
        self.tokens_moved = 0
        self.tokens_by_kind: Dict[str, int] = {}

    # ------------------------------------------------------------------
    # pool registry
    # ------------------------------------------------------------------
    def register(self, endpoint) -> None:
        """Make ``endpoint``'s KV pools addressable as a transfer source
        or destination."""
        self._pools[endpoint.name] = endpoint

    def deregister(self, name: str) -> None:
        """Drop a detached endpoint's pools from the registry."""
        self._pools.pop(name, None)

    def endpoint(self, name: str):
        """The registered endpoint for ``name`` (None if unknown)."""
        return self._pools.get(name)

    # ------------------------------------------------------------------
    # transfers
    # ------------------------------------------------------------------
    def link_time(self, device_model, n_tokens: int) -> float:
        """Modeled wire time for ``n_tokens`` of KV on ``device_model``'s
        inter-device link."""
        return device_model.transfer_time(n_tokens)

    def transfer(self, req: Request, *, src: str, dst: str,
                 deliver: Callable[[Request], None], when: float,
                 n_tokens: Optional[int] = None, device_model=None,
                 charge: str = "ingest",
                 kind: str = "handoff") -> TransferHandle:
        """Move ``req`` (carrying its KV payload) from pool ``src`` to
        pool ``dst``: schedule ``deliver(req)`` into the event loop at
        ``when`` (plus wire time under ``charge="link"``). The delivery
        closure re-checks cancellation, so a cancel landing between post
        and drain never resurrects the request at the destination."""
        if charge not in CHARGE_MODES:
            raise ValueError(f"unknown charge mode {charge!r}; "
                             f"choose from {CHARGE_MODES}")
        if n_tokens is None:
            n_tokens = req.partial_len if req.partial_len else req.context_len
        link = (self.link_time(device_model, n_tokens)
                if device_model is not None else 0.0)
        handle = TransferHandle(req_id=req.req_id, src=src, dst=dst,
                                n_tokens=int(n_tokens), t_post=when,
                                link_time=link, kind=kind)
        t_arrive = when + link if charge == "link" else when
        if charge == "link":
            req.ready_time = max(req.ready_time, t_arrive)
        self._inflight[handle.req_id] = handle
        self.n_transfers += 1

        def _fire(h=handle, r=req, t=t_arrive):
            if self._inflight.get(h.req_id) is h:
                del self._inflight[h.req_id]
            tracer = getattr(self._runtime, "tracer", None)
            if h.state == "cancelled" or r.state is ReqState.CANCELLED:
                h.state = "cancelled"
                self.n_cancelled += 1
                if tracer is not None:
                    tracer.instant(tracer.track_for(h.src), "kv_cancelled",
                                   t, {"req": h.req_id, "kind": h.kind,
                                       "dst": h.dst})
                return
            h.state = "delivered"
            self.tokens_moved += h.n_tokens
            self.tokens_by_kind[h.kind] = (
                self.tokens_by_kind.get(h.kind, 0) + h.n_tokens)
            if tracer is not None:
                # both halves of the flow arrow are emitted at delivery,
                # so every send pairs with exactly one receive (cancelled
                # transfers surface as kv_cancelled instants instead)
                fid = tracer.new_flow_id()
                args = {"req": h.req_id, "kind": h.kind,
                        "tokens": h.n_tokens, "src": h.src, "dst": h.dst}
                tracer.flow_start(tracer.track_for(h.src), "kv_send",
                                  h.t_post, fid, args)
                tracer.flow_end(tracer.track_for(h.dst), "kv_recv",
                                t, fid, args)
                tracer.counter(tracer.control, "transfer_tokens", t,
                               {h.kind: self.tokens_by_kind[h.kind]})
            deliver(r)

        if self._runtime is not None:
            self._runtime.post(t_arrive, _fire)
        else:
            _fire()
        return handle

    def cancel(self, req_id: str) -> bool:
        """Cancel the in-flight transfer for ``req_id``, if any."""
        h = self._inflight.get(req_id)
        return h.cancel() if h is not None else False

    @property
    def n_inflight(self) -> int:
        """Transfers posted but not yet delivered or cancelled."""
        return len(self._inflight)

    def stats(self) -> Dict[str, float]:
        """Counter snapshot for benchmarks and operator dashboards."""
        out: Dict[str, float] = {
            "n_transfers": self.n_transfers,
            "n_cancelled": self.n_cancelled,
            "n_inflight": self.n_inflight,
            "tokens_moved": self.tokens_moved,
        }
        for kind, n in self.tokens_by_kind.items():
            out[f"tokens_{kind}"] = n
        return out
