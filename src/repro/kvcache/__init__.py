"""Paged-KV cache layer: block accounting and cluster-scale KV movement."""
from repro.kvcache.allocator import BlockAllocator
from repro.kvcache.transfer import TransferEngine, TransferHandle

__all__ = ["BlockAllocator", "TransferEngine", "TransferHandle"]
