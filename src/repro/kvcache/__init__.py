from repro.kvcache.allocator import BlockAllocator

__all__ = ["BlockAllocator"]
