"""Paged-KV block allocator (host-side bookkeeping, vLLM-style).

The Balancer (paper Alg. 1) gates admission on ``N_free < ceil(L_in / N_size)``
— this allocator is the source of truth for that check. The functional
engine allocates blocks per request as its context grows; the Pallas
paged-attention kernel consumes the same block tables on TPU.

Prefix caching (``prefix_cache=True``, default off — the off path is
bit-identical to the plain allocator):

  * every block carries a refcount; blocks of a finished request whose
    content is registered in the prefix index are RETAINED at refcount 0
    in an LRU list instead of returning to the free list;
  * the index is a hash-of-token-ids chain (vLLM-style): block ``i`` of a
    sequence hashes ``(parent_chain_hash, token_ids[i*bs:(i+1)*bs])``, so
    ``lookup_prefix`` walks full blocks hash-by-hash and ``share_blocks``
    bumps their refcounts into a new request's block table;
  * on partial-block divergence (the request's tokens leave a cached
    block's content mid-block, or the match is capped mid-block) the
    request takes a private copy-on-write block covering the common
    prefix — shared blocks are immutable, so nobody's view corrupts;
  * cached refcount-0 blocks are *evictable*: ``num_free`` counts them,
    which keeps the free-block signal the Balancer reads honest (a cached
    block never blocks admission — allocation evicts LRU-first on demand).

Host-memory tier (``host_blocks > 0``, requires ``prefix_cache``): when a
cached refcount-0 block is evicted to satisfy an allocation, its indexed
content is *demoted* to a modeled CPU-DRAM tier (an LRU of up to
``host_blocks`` entries keyed by chain hash) instead of being dropped.
The prefix walk crosses tiers transparently, so ``lookup_prefix`` still
sees demoted chains and ``share_blocks`` *promotes* matched host entries
back into GPU blocks on placement. Tier moves are charged as PCIe traffic:
the allocator accumulates moved tokens and the engine drains them via
:meth:`take_pending_host_transfer_tokens` into the iteration's overlap
budget (``DeviceModel.host_kv_time``). ``num_free`` never counts host
entries — the Balancer's Algorithm-1 signal stays a GPU-pool truth — and
executors with physical pools mirror the moves through the ``on_demote``
/ ``on_promote`` / ``on_host_evict`` hooks.
"""
from __future__ import annotations

import hashlib
import math
from collections import OrderedDict
from typing import Dict, List, Optional

import numpy as np


def _chain(parent: bytes, tokens: np.ndarray) -> bytes:
    """Chained content hash of one block: parent digest + token ids."""
    return hashlib.blake2b(parent + np.ascontiguousarray(tokens).tobytes(),
                           digest_size=16).digest()


def _common_prefix_len(a: np.ndarray, b: np.ndarray) -> int:
    m = min(len(a), len(b))
    if m == 0:
        return 0
    eq = a[:m] == b[:m]
    return m if eq.all() else int(np.argmax(~eq))


class BlockAllocator:
    """Paged-KV block accounting for one engine: free list, per-request
    block tables, optional refcounted prefix cache, and an optional
    host-memory ("CPU") tier that demoted cache blocks spill into."""

    def __init__(self, num_blocks: int, block_size: int,
                 prefix_cache: bool = False, host_blocks: int = 0):
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.prefix_cache = prefix_cache
        self.host_blocks = int(host_blocks)
        if self.host_blocks and not prefix_cache:
            raise ValueError("host_blocks requires prefix_cache: the host "
                             "tier holds demoted prefix-cache content")
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._owned: Dict[str, List[int]] = {}
        # Physical-copy hook for executors that keep real KV behind these
        # block ids (PagedRealExecutor). Called as ``on_cow(dst, src,
        # n_tokens)`` when a copy-on-write block is taken so the backend
        # can clone the first ``n_tokens`` rows of ``src`` into ``dst``.
        # Simulation-only engines leave it None.
        self.on_cow = None
        self._shared: Dict[str, int] = {}         # req -> cache-shared tokens
        # --- prefix-cache state (all empty when prefix_cache is off) ----
        self._ref: Dict[int, int] = {}            # block -> live refcount
        self._lru: OrderedDict = OrderedDict()    # refcount-0 cached blocks
        self._block_hash: Dict[int, bytes] = {}   # indexed block -> chain hash
        self._hash_to_block: Dict[bytes, int] = {}
        self._block_parent: Dict[int, bytes] = {}
        self._block_tokens: Dict[int, np.ndarray] = {}
        self._children: Dict[bytes, List[int]] = {}
        # --- host-memory tier (empty when host_blocks == 0) -------------
        # chain hash -> (parent chain hash, block tokens); LRU order.
        # Disjoint from the GPU index by invariant: a hash lives in
        # ``_hash_to_block`` or ``_host``, never both.
        self._host: "OrderedDict[bytes, tuple]" = OrderedDict()
        # Physical-move hooks, mirroring ``on_cow``: ``on_demote(block,
        # key)`` fires while the demoted block's pool row is still intact
        # (save it host-side), ``on_promote(block, key)`` after a GPU
        # block was taken for the promoted content (restore the row), and
        # ``on_host_evict(key)`` when a host entry is dropped.
        self.on_demote = None
        self.on_promote = None
        self.on_host_evict = None
        # flight recorder back-reference: the allocator has no clock of
        # its own, so InferenceService.start_trace points this at the
        # owning engine (clock + tracer + track). None = zero overhead.
        self.trace_engine = None
        self._pending_host_tokens = 0   # PCIe traffic awaiting charge
        # counters (benchmark / metrics surface)
        self.n_prefix_hits = 0      # share_blocks calls that reused tokens
        self.n_tokens_reused = 0    # prompt tokens whose prefill was skipped
        self.n_cow_copies = 0       # partial-block divergence copies
        self.n_evictions = 0        # cached blocks reclaimed for allocation
        self.n_demotions = 0        # blocks spilled GPU -> host tier
        self.n_promotions = 0       # host entries pulled back into GPU blocks
        self.n_host_evictions = 0   # host entries dropped (capacity/collision)

    @property
    def num_free(self) -> int:
        """Blocks available to allocate. Cached refcount-0 blocks count:
        they are reclaimed LRU-first on demand, so the Balancer's
        Algorithm-1 admission signal must treat them as free."""
        return len(self._free) + len(self._lru)

    def blocks_needed(self, n_tokens: int) -> int:
        """Blocks covering ``n_tokens`` at this block size."""
        return math.ceil(n_tokens / self.block_size)

    def can_allocate(self, n_tokens: int) -> bool:
        """Whether a fresh ``n_tokens`` allocation would fit right now."""
        return self.blocks_needed(n_tokens) <= self.num_free

    # ------------------------------------------------------------------
    # block supply (free list first, then LRU eviction of cached blocks)
    # ------------------------------------------------------------------
    def _evict_lru(self, exclude: Optional[int] = None) -> None:
        for b in self._lru:
            if b != exclude:
                if self.host_blocks:
                    self._demote(b)
                else:
                    self._deindex(b)
                self._free.append(b)
                self.n_evictions += 1
                return
        raise MemoryError("no evictable cached block")

    def _demote(self, b: int) -> None:
        """Spill an evicted cache block's content to the host tier. Partial
        tail blocks are dropped instead: the cross-tier walk matches full
        blocks only, so a demoted partial could never be promoted back."""
        h = self._block_hash[b]
        parent = self._block_parent[b]
        tokens = self._block_tokens[b]
        self._deindex(b)
        if len(tokens) < self.block_size:
            return
        self._host[h] = (parent, tokens)          # MRU end
        self.n_demotions += 1
        self._pending_host_tokens += len(tokens)
        eng = self.trace_engine
        if eng is not None and eng.tracer is not None:
            eng.tracer.instant(eng.trace_track, "kv_demote", eng.clock,
                               {"tokens": len(tokens)})
        if self.on_demote is not None:
            self.on_demote(b, h)
        while len(self._host) > self.host_blocks:
            k, _ = self._host.popitem(last=False)  # oldest entry
            self.n_host_evictions += 1
            if self.on_host_evict is not None:
                self.on_host_evict(k)

    def _promote(self, key: bytes) -> int:
        """Pull one host-tier entry back into a freshly taken GPU block
        (caller guarantees ``num_free >= 1``) and re-register it in the
        prefix index at refcount 1."""
        parent, tokens = self._host.pop(key)
        blk = self._take_block()
        self._block_hash[blk] = key
        self._hash_to_block[key] = blk
        self._block_parent[blk] = parent
        self._block_tokens[blk] = tokens
        self._children.setdefault(parent, []).append(blk)
        self._ref[blk] = 1
        self.n_promotions += 1
        self._pending_host_tokens += len(tokens)
        eng = self.trace_engine
        if eng is not None and eng.tracer is not None:
            eng.tracer.instant(eng.trace_track, "kv_promote", eng.clock,
                               {"tokens": len(tokens)})
        if self.on_promote is not None:
            self.on_promote(blk, key)
        return blk

    def take_pending_host_transfer_tokens(self) -> int:
        """Drain the tokens moved across PCIe (demotions + promotions)
        since the last call — the engine charges them into the current
        iteration's transfer-overlap budget."""
        n, self._pending_host_tokens = self._pending_host_tokens, 0
        return n

    @property
    def host_resident_blocks(self) -> int:
        """Entries currently held in the host-memory tier."""
        return len(self._host)

    def _deindex(self, b: int) -> None:
        """Drop a block from the prefix index (eviction). Indexed
        descendants keyed under its chain hash become unreachable to the
        walk and simply age out of the LRU."""
        self._lru.pop(b, None)
        h = self._block_hash.pop(b)
        del self._hash_to_block[h]
        parent = self._block_parent.pop(b)
        self._block_tokens.pop(b)
        sibs = self._children[parent]
        sibs.remove(b)
        if not sibs:
            del self._children[parent]

    def _take_block(self, exclude: Optional[int] = None) -> int:
        if not self._free:
            self._evict_lru(exclude)
        return self._free.pop()

    def allocate(self, req_id: str, n_tokens: int) -> List[int]:
        """Give ``req_id`` fresh blocks for ``n_tokens`` (MemoryError if
        the pool, including reclaimable cache, cannot cover it)."""
        need = self.blocks_needed(n_tokens)
        if need > self.num_free:
            raise MemoryError(f"out of KV blocks: need {need}, free {self.num_free}")
        blocks = [self._take_block() for _ in range(need)]
        if self.prefix_cache:
            for b in blocks:
                self._ref[b] = 1
        self._owned.setdefault(req_id, []).extend(blocks)
        return blocks

    def owned_blocks(self, req_id: str) -> int:
        """Blocks currently held by a request (0 if unknown)."""
        return len(self._owned.get(req_id, ()))

    def can_extend_to(self, req_id: str, n_tokens: int) -> bool:
        """Whether growing ``req_id`` to ``n_tokens`` total would fit."""
        return (self.blocks_needed(n_tokens) - self.owned_blocks(req_id)
                <= self.num_free)

    def extend_to(self, req_id: str, n_tokens: int) -> List[int]:
        """Grow a request's allocation until it covers ``n_tokens`` total
        (no-op if it already does). This is the dynamic-growth entry point
        the iteration scheduler uses as ``context_len`` advances."""
        have = self.owned_blocks(req_id)
        extra = max(0, self.blocks_needed(n_tokens) - have)
        if extra > self.num_free:
            raise MemoryError(
                f"out of KV blocks: need {extra}, free {self.num_free}")
        blocks = [self._take_block() for _ in range(extra)]
        if blocks:
            if self.prefix_cache:
                for b in blocks:
                    self._ref[b] = 1
            self._owned.setdefault(req_id, []).extend(blocks)
        return blocks

    def free(self, req_id: str,
             cache_tokens: Optional[np.ndarray] = None) -> None:
        """Release a request's blocks. With prefix caching, pass the token
        ids the blocks hold (prompt + generated) to register their content
        in the prefix index before the refcounts drop: refcount-0 indexed
        blocks are retained in the LRU cache, everything else returns to
        the free list. Without ``cache_tokens`` (preemption, or caching
        off) nothing is registered."""
        blocks = self._owned.pop(req_id, [])
        self._shared.pop(req_id, None)
        if not self.prefix_cache:
            self._free.extend(blocks)
            return
        if cache_tokens is not None and blocks:
            self._register(blocks, np.asarray(cache_tokens, np.int32))
        for b in blocks:
            self._ref[b] -= 1
            if self._ref[b] == 0:
                del self._ref[b]
                if b in self._block_hash:
                    self._lru[b] = None           # retained, MRU end
                else:
                    self._free.append(b)

    # ------------------------------------------------------------------
    # prefix index
    # ------------------------------------------------------------------
    def _register(self, blocks: List[int], tokens: np.ndarray) -> None:
        """Index each block of a released sequence under its chain hash
        (full blocks, plus the partial tail — divergence from a partial
        block is served by copy-on-write). First registration of a given
        content wins; a block whose hash is already mapped elsewhere stays
        unindexed and frees normally."""
        h = b""
        for i, blk in enumerate(blocks):
            lo = i * self.block_size
            hi = min(lo + self.block_size, len(tokens))
            if hi <= lo:
                break
            parent, h = h, _chain(h, tokens[lo:hi])
            if blk in self._block_hash:
                continue            # already indexed (shared prefix block)
            if h in self._hash_to_block:
                continue            # duplicate content; existing entry wins
            if h in self._host:
                # the content just re-materialized on the GPU: keep the
                # tiers disjoint — the fresh GPU copy is authoritative
                del self._host[h]
                self.n_host_evictions += 1
                if self.on_host_evict is not None:
                    self.on_host_evict(h)
            self._block_hash[blk] = h
            self._hash_to_block[h] = blk
            self._block_parent[blk] = parent
            self._block_tokens[blk] = tokens[lo:hi].copy()
            self._children.setdefault(parent, []).append(blk)

    def _match_prefix(self, tokens: np.ndarray, max_tokens: Optional[int]):
        """The single source of truth both ``lookup_prefix`` (read-only
        promise) and ``share_blocks`` (placement) use: walk the full-block
        hash chain — crossing into the host tier wherever a link was
        demoted — then find the best common prefix into one GPU-cached
        block past the divergence point. Returns ``(keys, n_full, src,
        src_len)`` — the matched chain hashes (each resolvable in exactly
        one tier), the tokens they cover, and the CoW source block (with
        its matched token count), if any."""
        tokens = np.asarray(tokens, np.int32)
        limit = len(tokens) if max_tokens is None else min(max_tokens,
                                                           len(tokens))
        keys: List[bytes] = []
        n, h = 0, b""
        while n + self.block_size <= limit:
            h2 = _chain(h, tokens[n:n + self.block_size])
            if h2 not in self._hash_to_block and h2 not in self._host:
                break
            keys.append(h2)
            n, h = n + self.block_size, h2
        src, src_len = None, 0
        for b in self._children.get(h, ()):
            k = _common_prefix_len(tokens[n:limit], self._block_tokens[b])
            if k > src_len:
                src, src_len = b, k
        return keys, n, src, src_len

    def lookup_prefix(self, tokens: np.ndarray,
                      max_tokens: Optional[int] = None) -> int:
        """Tokens of ``tokens`` whose KV is reusable from the cache right
        now — either tier: the longest full-block hash-chain match (host
        links count; they promote at share time), plus the longest common
        prefix into one cached block past it (served by CoW at share
        time). Read-only — used by planners and affinity routers."""
        if not self.prefix_cache:
            return 0
        _, n, _, src_len = self._match_prefix(tokens, max_tokens)
        return n + src_len

    def share_blocks(self, req_id: str, tokens: np.ndarray,
                     max_tokens: Optional[int] = None) -> int:
        """Seed a new request's block table from the prefix cache: bump
        refcounts on every fully-matched block, and on partial-block
        divergence take a copy-on-write block holding the common prefix
        (skipped when no block is available for the copy). Returns the
        number of prompt tokens whose prefill is thereby skipped. Must be
        called before the request owns any blocks."""
        if not self.prefix_cache:
            return 0
        assert not self._owned.get(req_id), "share_blocks before allocate"
        keys, n, src, src_len = self._match_prefix(tokens, max_tokens)
        table: List[int] = []
        n = 0
        for key in keys:
            # resolve each link live: a promotion below may have evicted
            # (or itself demoted) blocks matched further along the chain
            blk = self._hash_to_block.get(key)
            if blk is not None:
                if blk not in self._ref:
                    self._lru.pop(blk)            # resurrect from cache
                    self._ref[blk] = 0
                self._ref[blk] += 1
            elif key in self._host and self.num_free >= 1:
                blk = self._promote(key)
            else:
                # chain broken mid-walk (host entry displaced, or no GPU
                # block left to promote into): keep the contiguous prefix
                src, src_len = None, 0
                break
            table.append(blk)
            n += self.block_size
        if src is not None and src_len > 0:
            # partial-block divergence -> copy-on-write; the promote pass
            # can displace cached blocks, so re-check src is still indexed
            spare = self.num_free - (1 if src in self._lru else 0)
            if spare >= 1 and src in self._block_tokens:
                cow = self._take_block(exclude=src)
                if self.on_cow is not None:
                    self.on_cow(cow, src, src_len)
                self._ref[cow] = 1
                table.append(cow)
                n += src_len
                self.n_cow_copies += 1
        if table:
            self._owned[req_id] = table
        if n > 0:
            self.n_prefix_hits += 1
            self.n_tokens_reused += n
            self._shared[req_id] = n
        return n

    def shared_tokens(self, req_id: str) -> int:
        """Tokens at the head of ``req_id``'s context that came from the
        prefix cache via :meth:`share_blocks`. Block-pool executors must
        not overwrite them on inject: the full-block share is immutable
        shared storage, and the CoW tail was already cloned physically."""
        return self._shared.get(req_id, 0)

    def adopt_prefix(self, tokens: np.ndarray, n_tokens: int) -> int:
        """Replicate the first ``n_tokens`` of ``tokens`` into this cache
        as refcount-0 retained blocks — the receiving half of a
        cross-endpoint prefix fetch (the content arrived from a peer's
        pool via the transfer engine; link cost is charged by the caller).
        Full blocks only; links already resident (either tier) are touched
        to MRU instead of duplicated. Returns the tokens *newly
        materialized* here (0 when caching is off or the pool is fully
        owned)."""
        if not self.prefix_cache:
            return 0
        tokens = np.asarray(tokens, np.int32)
        limit = min(int(n_tokens), len(tokens))
        n, h, adopted = 0, b"", 0
        while n + self.block_size <= limit:
            h2 = _chain(h, tokens[n:n + self.block_size])
            if h2 in self._hash_to_block:
                blk = self._hash_to_block[h2]
                if blk in self._lru:
                    self._lru.move_to_end(blk)    # fetched prefix is hot
            elif h2 in self._host:
                self._host.move_to_end(h2)
            else:
                if self.num_free < 1:
                    break
                blk = self._take_block()
                self._block_hash[blk] = h2
                self._hash_to_block[h2] = blk
                self._block_parent[blk] = h
                self._block_tokens[blk] = tokens[n:n + self.block_size].copy()
                self._children.setdefault(h, []).append(blk)
                self._lru[blk] = None             # refcount-0, evictable
                adopted += self.block_size
            n, h = n + self.block_size, h2
        return adopted

    def block_table(self, req_id: str) -> List[int]:
        """The request's current block table (copy), in context order."""
        return list(self._owned.get(req_id, []))

    def check_invariants(self) -> None:
        """Assert the full partition/accounting story; tests call this
        after every scenario. Covers both tiers when a host tier is on."""
        owned = [b for bs in self._owned.values() for b in bs]
        if not self.prefix_cache:
            assert len(owned) == len(set(owned)), "double-allocated block"
            assert len(owned) + len(self._free) == self.num_blocks, \
                "leaked blocks"
            assert not (set(owned) & set(self._free)), \
                "block both owned and free"
            return
        # refcount-consistent accounting: every block is exactly one of
        # owned (ref >= 1), cached (ref 0, indexed, in LRU), or free
        for bs in self._owned.values():
            assert len(bs) == len(set(bs)), "block twice in one table"
        counts: Dict[int, int] = {}
        for b in owned:
            counts[b] = counts.get(b, 0) + 1
        assert counts == self._ref, \
            f"refcounts disagree with block tables: {counts} vs {self._ref}"
        owned_set, lru_set, free_set = (set(counts), set(self._lru),
                                        set(self._free))
        assert not owned_set & lru_set, "owned block in LRU cache"
        assert not owned_set & free_set, "block both owned and free"
        assert not lru_set & free_set, "block both cached and free"
        assert len(owned_set | lru_set | free_set) == self.num_blocks, \
            "leaked blocks"
        for b in lru_set:
            assert b in self._block_hash, "unindexed block retained in LRU"
        assert set(self._block_hash) == set(self._hash_to_block.values())
        for b, h in self._block_hash.items():
            assert self._hash_to_block[h] == b, "index maps disagree"
            assert b in self._block_tokens and b in self._block_parent
            assert b in self._children[self._block_parent[b]]
        # --- host-tier accounting ---------------------------------------
        if not self.host_blocks:
            assert not self._host, "host entries with the tier disabled"
            return
        assert len(self._host) <= self.host_blocks, "host tier over capacity"
        assert not (set(self._host) & set(self._hash_to_block)), \
            "chain hash resident in both tiers"
        for k, (parent, toks) in self._host.items():
            assert len(toks) == self.block_size, \
                "partial block demoted to host tier"
