"""Paged-KV block allocator (host-side bookkeeping, vLLM-style).

The Balancer (paper Alg. 1) gates admission on ``N_free < ceil(L_in / N_size)``
— this allocator is the source of truth for that check. The functional
engine allocates blocks per request as its context grows; the Pallas
paged-attention kernel consumes the same block tables on TPU.
"""
from __future__ import annotations

import math
from typing import Dict, List


class BlockAllocator:
    def __init__(self, num_blocks: int, block_size: int):
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._owned: Dict[str, List[int]] = {}

    @property
    def num_free(self) -> int:
        return len(self._free)

    def blocks_needed(self, n_tokens: int) -> int:
        return math.ceil(n_tokens / self.block_size)

    def can_allocate(self, n_tokens: int) -> bool:
        return self.blocks_needed(n_tokens) <= self.num_free

    def allocate(self, req_id: str, n_tokens: int) -> List[int]:
        need = self.blocks_needed(n_tokens)
        if need > self.num_free:
            raise MemoryError(f"out of KV blocks: need {need}, free {self.num_free}")
        blocks = [self._free.pop() for _ in range(need)]
        self._owned.setdefault(req_id, []).extend(blocks)
        return blocks

    def owned_blocks(self, req_id: str) -> int:
        """Blocks currently held by a request (0 if unknown)."""
        return len(self._owned.get(req_id, ()))

    def can_extend_to(self, req_id: str, n_tokens: int) -> bool:
        return (self.blocks_needed(n_tokens) - self.owned_blocks(req_id)
                <= self.num_free)

    def extend_to(self, req_id: str, n_tokens: int) -> List[int]:
        """Grow a request's allocation until it covers ``n_tokens`` total
        (no-op if it already does). This is the dynamic-growth entry point
        the iteration scheduler uses as ``context_len`` advances."""
        have = self.owned_blocks(req_id)
        extra = max(0, self.blocks_needed(n_tokens) - have)
        if extra > self.num_free:
            raise MemoryError(
                f"out of KV blocks: need {extra}, free {self.num_free}")
        blocks = [self._free.pop() for _ in range(extra)]
        if blocks:
            self._owned.setdefault(req_id, []).extend(blocks)
        return blocks

    def free(self, req_id: str) -> None:
        blocks = self._owned.pop(req_id, [])
        self._free.extend(blocks)

    def block_table(self, req_id: str) -> List[int]:
        return list(self._owned.get(req_id, []))

    def check_invariants(self) -> None:
        owned = [b for bs in self._owned.values() for b in bs]
        assert len(owned) == len(set(owned)), "double-allocated block"
        assert len(owned) + len(self._free) == self.num_blocks, "leaked blocks"
        assert not (set(owned) & set(self._free)), "block both owned and free"
