"""AdamW in pure JAX (no optax dependency) + cosine LR schedule."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def cosine_lr(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def init_adamw(params) -> dict:
    def zeros(p):
        return jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def _global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)))
                        for leaf in leaves))


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, stats)."""
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = cosine_lr(cfg, step)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m2 / (1 - cfg.b1 ** step)
        vh = v2 / (1 - cfg.b2 ** step)
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"step": step, "m": new_m, "v": new_v}, {
        "grad_norm": gnorm, "lr": lr}
