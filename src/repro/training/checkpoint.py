"""Sharded-friendly npz checkpointing (no orbax/tensorstore in this env).

Leaves are flattened with '/'-joined path keys. For multi-host use each host
would write its addressable shards; here (single host) we write full arrays.
"""
from __future__ import annotations

import os
from typing import Dict

import jax
import numpy as np


def _flatten(params) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, params, opt_state=None, step: int = 0):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    blob = {f"params/{k}": v for k, v in _flatten(params).items()}
    if opt_state is not None:
        blob.update({f"opt/{k}": v for k, v in _flatten(opt_state).items()})
    blob["meta/step"] = np.asarray(step)
    np.savez(path, **blob)


def load_checkpoint(path: str, params_template, opt_template=None):
    """Restores into the structure of the provided templates."""
    with np.load(path) as z:
        def restore(template, prefix):
            flat = _flatten(template)
            restored = {k: z[f"{prefix}/{k}"] for k in flat}
            leaves_order = list(flat.keys())
            treedef = jax.tree_util.tree_structure(template)
            return treedef.unflatten([restored[k] for k in leaves_order])

        params = restore(params_template, "params")
        opt = restore(opt_template, "opt") if opt_template is not None else None
        step = int(z["meta/step"])
    return params, opt, step
