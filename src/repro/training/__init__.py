from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.optimizer import AdamWConfig, adamw_update, init_adamw
from repro.training.trainer import Trainer, make_train_step

__all__ = ["AdamWConfig", "Trainer", "adamw_update", "init_adamw",
           "load_checkpoint", "make_train_step", "save_checkpoint"]
