"""Training loop: jit'd train_step (loss + AdamW), optional pjit sharding."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.training.data import TokenDataset, make_train_batch
from repro.training.optimizer import AdamWConfig, adamw_update, init_adamw


def make_train_step(model, opt_cfg: AdamWConfig) -> Callable:
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state, stats = adamw_update(opt_cfg, params, grads, opt_state)
        stats["loss"] = loss
        return params, opt_state, stats

    return train_step


@dataclasses.dataclass
class Trainer:
    model: object
    opt_cfg: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    batch_size: int = 8
    seq_len: int = 64

    def __post_init__(self):
        self.dataset = TokenDataset(self.model.cfg.vocab_size)
        self._step_fn = jax.jit(make_train_step(self.model, self.opt_cfg))

    def init(self, seed: int = 0):
        params = self.model.init_params(jax.random.PRNGKey(seed))
        return params, init_adamw(params)

    def run(self, params, opt_state, n_steps: int, log_every: int = 10,
            log: Optional[Callable] = print):
        losses = []
        t0 = time.time()
        for step in range(n_steps):
            batch = {k: jnp.asarray(v) for k, v in make_train_batch(
                self.model.cfg, self.batch_size, self.seq_len, step,
                self.dataset).items()}
            params, opt_state, stats = self._step_fn(params, opt_state, batch)
            losses.append(float(stats["loss"]))
            if log and step % log_every == 0:
                log(f"step {step:5d} loss {losses[-1]:.4f} "
                    f"lr {float(stats['lr']):.2e} "
                    f"gnorm {float(stats['grad_norm']):.3f} "
                    f"({(time.time()-t0)/(step+1)*1000:.0f} ms/step)")
        return params, opt_state, losses
