"""Token data pipeline: deterministic synthetic LM stream (zipfian tokens
with local structure), sharded global batches, and whisper-style
(embedding, token) pairs for the enc-dec / frontend-stub architectures."""
from __future__ import annotations

from typing import Optional

import numpy as np


class TokenDataset:
    """Deterministic pseudo-corpus: zipf-distributed tokens with Markov-ish
    bigram structure so the LM loss is learnable (tests assert it drops)."""

    def __init__(self, vocab_size: int, seed: int = 0):
        self.vocab = vocab_size
        rng = np.random.default_rng(seed)
        # bigram successor table: each token has a few likely successors
        self._succ = rng.integers(0, vocab_size, (vocab_size, 4))
        self._zipf_p = 1.0 / np.arange(1, vocab_size + 1)
        self._zipf_p /= self._zipf_p.sum()

    def batch(self, batch_size: int, seq_len: int, step: int) -> np.ndarray:
        """[B, seq_len+1] int32 (inputs + shifted labels)."""
        rng = np.random.default_rng(hash((step, 7)) % (2 ** 31))
        out = np.empty((batch_size, seq_len + 1), np.int32)
        tok = rng.choice(self.vocab, size=batch_size, p=self._zipf_p)
        for t in range(seq_len + 1):
            out[:, t] = tok
            branch = rng.random(batch_size) < 0.8
            succ_idx = rng.integers(0, 4, batch_size)
            nxt_struct = self._succ[tok, succ_idx]
            nxt_rand = rng.choice(self.vocab, size=batch_size, p=self._zipf_p)
            tok = np.where(branch, nxt_struct, nxt_rand)
        return out


def make_train_batch(cfg, batch_size: int, seq_len: int, step: int,
                     dataset: Optional[TokenDataset] = None):
    """Returns the model's `loss()` batch dict for any architecture family."""
    ds = dataset or TokenDataset(cfg.vocab_size, seed=0)
    tokens = ds.batch(batch_size, seq_len, step)
    if cfg.enc_dec:
        rng = np.random.default_rng(step)
        enc_len = min(cfg.enc_seq_len or 64, 64)
        enc = rng.standard_normal((batch_size, enc_len, cfg.d_model)).astype(np.float32)
        return {"enc_emb": enc, "tokens": tokens}
    return {"tokens": tokens}
