"""Iteration-level scheduling layer (Engine -> Scheduler -> Allocator).

The engine executes :class:`IterationPlan`\\ s; a :class:`Scheduler` policy
composes them from slots/queue/allocator state. See ``base.py`` for the
interface and ``policies.py`` for the shipped policies
(``fcfs`` / ``sarathi`` / ``sjf``).
"""
from repro.scheduling.base import (IterationPlan, PrefillChunk, Scheduler,
                                   SchedulerView, effective_state)
from repro.scheduling.policies import (SCHEDULERS, FCFSScheduler,
                                       SarathiScheduler, SJFScheduler,
                                       make_scheduler)

__all__ = [
    "IterationPlan", "PrefillChunk", "Scheduler", "SchedulerView",
    "effective_state",
    "SCHEDULERS", "FCFSScheduler", "SarathiScheduler", "SJFScheduler",
    "make_scheduler",
]
