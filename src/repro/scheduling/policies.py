"""The shipped batch-composition policies.

``fcfs``     — bit-compatible replica of the seed engine: strict FIFO
               admission (a blocked head blocks everyone behind it),
               conservative full-context KV reservation, and a single
               prefill chunk per iteration (the head PREFILL request).
``sarathi``  — Sarathi-SC-style multi-sequence chunk packing: several
               PREFILL requests share one token budget, admission skips
               past blocked heads, and KV blocks grow lazily with
               preemption-by-recompute on OOM.
``sjf``      — shortest-job-first priority (alias ``priority``): ready
               queue and prefill budget are ordered by remaining work
               (prefill left + output left), and preemption victims are
               the *longest* remaining jobs. Lazy KV like sarathi.
"""
from __future__ import annotations

from typing import List, Sequence

from repro.core.request import Request
from repro.scheduling.base import Scheduler


def _remaining_work(req: Request) -> int:
    """Tokens this request still has to produce/ingest — the SJF key."""
    return req.prefill_remaining + max(req.output_len - len(req.generated), 0)


class FCFSScheduler(Scheduler):
    name = "fcfs"
    default_skip_ahead = False
    default_lazy_kv = False
    max_prefill_seqs = 1           # head-of-slots chunk only, as the seed


class SarathiScheduler(Scheduler):
    name = "sarathi"
    default_skip_ahead = True
    default_lazy_kv = True
    max_prefill_seqs = None        # pack chunks until the budget is spent


class SJFScheduler(Scheduler):
    name = "sjf"
    default_skip_ahead = True
    default_lazy_kv = True
    max_prefill_seqs = None

    def admission_order(self, queue: Sequence[Request]) -> List[Request]:
        return sorted(queue, key=lambda r: (_remaining_work(r), r.arrival,
                                            r.req_id))

    def prefill_order(self, cands: List[Request]) -> List[Request]:
        return sorted(cands, key=lambda r: (_remaining_work(r), r.arrival,
                                            r.req_id))

    def victim_order(self, decode: List[Request]) -> List[Request]:
        # longest remaining job pays for the shortest ones
        return sorted(decode, key=lambda r: (_remaining_work(r), r.arrival,
                                             r.req_id), reverse=True)


SCHEDULERS = {
    "fcfs": FCFSScheduler,
    "sarathi": SarathiScheduler,
    "sjf": SJFScheduler,
    "priority": SJFScheduler,      # alias
}


def make_scheduler(policy: str, cfg) -> Scheduler:
    try:
        cls = SCHEDULERS[policy]
    except KeyError:
        raise KeyError(f"unknown sched policy {policy!r}; "
                       f"choose from {sorted(SCHEDULERS)}") from None
    return cls(cfg)
