"""Iteration-level scheduler: policy-driven batch composition.

One :class:`Scheduler` instance lives inside each :class:`~repro.core.engine.
Engine`. Every iteration the engine hands it a read-only
:class:`SchedulerView` (slots / queue / allocator / config / clock) and gets
back an :class:`IterationPlan` — which queued requests to admit, which
resident requests to preempt (recompute), which requests decode, and which
prefill chunks run, possibly several requests packed into one token budget.
The engine *executes* the plan; it no longer decides batch composition.

Two orthogonal knobs every policy composes:

  * **KV reservation** (``lazy_kv``): conservative policies reserve blocks
    for the full ``input_len + output_len`` at admission (the seed engine's
    behaviour — safe, never preempts, but wildly pessimistic for the
    free-block signal the Balancer's Algorithm 1 reads). Lazy policies
    reserve only the prompt (+1 token) and grow the allocation via
    ``BlockAllocator.extend_to`` as decode advances; when growth hits OOM
    the plan preempts low-priority requests by *recompute* (vLLM-style:
    release KV, fold generated tokens into the prompt, re-prefill later).
  * **Skip-ahead admission** (``skip_ahead``): whether a queued request
    that is ready and allocatable may be admitted past a blocked head
    (e.g. one still in PPI->CPI transit). Off for strict FCFS.

Planning happens *before* the engine ingests pending KV transfers, so the
scheduler reasons about post-ingest ("effective") states: a TRANSFER
request whose context already covers its prompt decodes this very
iteration, one that does not becomes a prefill candidate.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import List, Optional, Sequence

from repro.core.request import ReqState, Request


def effective_state(req: Request) -> ReqState:
    """The state a request reaches after KV ingest / admission this
    iteration (TRANSFER and WAITING resolve by context coverage)."""
    if req.state in (ReqState.WAITING, ReqState.TRANSFER):
        return (ReqState.RUNNING if req.context_len >= req.input_len
                else ReqState.PREFILL)
    return req.state


@dataclasses.dataclass
class PrefillChunk:
    req: Request
    chunk_len: int


@dataclasses.dataclass
class IterationPlan:
    """What one engine iteration executes."""
    admit: List[Request] = dataclasses.field(default_factory=list)
    preempt: List[Request] = dataclasses.field(default_factory=list)
    decode: List[Request] = dataclasses.field(default_factory=list)
    prefill: List[PrefillChunk] = dataclasses.field(default_factory=list)

    @property
    def n_prefill_tokens(self) -> int:
        return sum(c.chunk_len for c in self.prefill)


@dataclasses.dataclass
class SchedulerView:
    """Read-only engine state a policy plans against."""
    clock: float
    slots: Sequence[Optional[Request]]
    queue: Sequence[Request]
    allocator: object          # repro.kvcache.BlockAllocator
    cfg: object                # repro.core.engine.EngineConfig

    def free_slot_indices(self, preempt: Sequence[Request] = ()) -> List[int]:
        gone = {id(r) for r in preempt}
        return [i for i, r in enumerate(self.slots)
                if r is None or id(r) in gone]

    def residents(self, admit: Sequence[Request] = (),
                  preempt: Sequence[Request] = ()) -> List[Request]:
        """Resident requests after applying ``admit``/``preempt``, in slot
        order. Admissions fill free slots lowest-index-first in admit
        order — exactly how the engine assigns slots, so the plan's
        request ordering matches the executed one."""
        gone = {id(r) for r in preempt}
        occ = {i: r for i, r in enumerate(self.slots)
               if r is not None and id(r) not in gone}
        for i, req in zip(self.free_slot_indices(preempt), admit):
            occ[i] = req
        return [occ[i] for i in sorted(occ)]


class Scheduler(abc.ABC):
    """Batch-composition policy. Subclasses set the class knobs and
    override the ordering hooks; the template methods below do the
    slot/block accounting once, identically to how the engine applies
    the plan."""

    name: str = "?"
    default_skip_ahead = False     # may queued requests pass a blocked head?
    default_lazy_kv = False        # lazy paged-KV growth (vs full reserve)
    max_prefill_seqs: Optional[int] = None   # None = pack until budget spent

    def __init__(self, cfg):
        self.cfg = cfg
        self.skip_ahead = (cfg.skip_ahead if cfg.skip_ahead is not None
                           else self.default_skip_ahead)
        self.lazy_kv = (cfg.lazy_kv if cfg.lazy_kv is not None
                        else self.default_lazy_kv)
        if cfg.decode_only:
            # a decode-only instance has no prefill path, so preemption-
            # by-recompute is unavailable; reserve conservatively instead
            self.lazy_kv = False

    # ------------------------------------------------------------------
    # ordering hooks (the policy)
    # ------------------------------------------------------------------
    def admission_order(self, queue: Sequence[Request]) -> List[Request]:
        """Queue scan order for admission (default: FIFO)."""
        return list(queue)

    def prefill_order(self, cands: List[Request]) -> List[Request]:
        """Order in which prefill candidates claim token budget
        (default: slot order, i.e. admission order)."""
        return cands

    def victim_order(self, decode: List[Request]) -> List[Request]:
        """Preemption victims, first victim first (default: newest
        arrival goes first, vLLM's recompute discipline)."""
        return sorted(decode, key=lambda r: (r.arrival, r.req_id),
                      reverse=True)

    # ------------------------------------------------------------------
    # KV accounting
    # ------------------------------------------------------------------
    def admission_tokens(self, req: Request) -> int:
        """Tokens' worth of KV blocks reserved when admitting ``req``."""
        if self.lazy_kv:
            # prompt + the first generated token; decode growth extends
            return req.input_len + (1 if req.output_len > 0 else 0)
        return req.input_len + req.output_len        # seed behaviour

    def watermark_blocks(self, view: SchedulerView) -> int:
        """Free-block headroom lazy admission keeps back to damp
        admit->OOM->preempt thrash (vLLM's 1% watermark)."""
        if not self.lazy_kv:
            return 0
        return max(1, view.allocator.num_blocks // 100)

    # ------------------------------------------------------------------
    # template: the plan
    # ------------------------------------------------------------------
    def plan(self, view: SchedulerView) -> IterationPlan:
        preempt: List[Request] = []
        if self.lazy_kv:
            running = [r for r in view.residents()
                       if effective_state(r) is ReqState.RUNNING]
            preempt = self._preempt_for_growth(view, running)
        admit = self.select_admissions(view, preempt)
        residents = view.residents(admit, preempt)
        decode = [r for r in residents
                  if effective_state(r) is ReqState.RUNNING]
        prefill = self.pack_prefill(view, residents, decode)
        return IterationPlan(admit=admit, preempt=preempt, decode=decode,
                             prefill=prefill)

    def select_admissions(self, view: SchedulerView,
                          preempt: Sequence[Request] = ()) -> List[Request]:
        """Queue -> slots this iteration, simulating the exact slot and
        block bookkeeping the engine will perform."""
        admit: List[Request] = []
        free_slots = len(view.free_slot_indices(preempt))
        free_blocks = view.allocator.num_free
        if self.lazy_kv:
            # blocks the surviving decoders will claim via extend_to
            preempt_ids = {id(r) for r in preempt}
            for r in view.residents():
                if (effective_state(r) is ReqState.RUNNING
                        and id(r) not in preempt_ids):
                    free_blocks -= max(
                        0, view.allocator.blocks_needed(r.total_ctx)
                        - view.allocator.owned_blocks(r.req_id))
            for r in preempt:
                free_blocks += view.allocator.owned_blocks(r.req_id)
        watermark = self.watermark_blocks(view)
        any_resident = any(r is not None for r in view.slots) or bool(preempt)
        for req in self.admission_order(view.queue):
            if len(admit) >= free_slots:
                break
            if req.ready_time > view.clock:
                if self.skip_ahead:
                    continue
                break
            if self.lazy_kv and view.allocator.blocks_needed(
                    req.input_len + req.output_len) > view.allocator.num_blocks:
                # the request's final context can never fit even with the
                # pool to itself: growth would OOM with no victim left.
                # Refuse admission — the same stall a conservative policy
                # gives an oversized request, instead of a mid-run crash.
                if self.skip_ahead:
                    continue
                break
            need = view.allocator.blocks_needed(self.admission_tokens(req))
            # the first admission into an idle engine bypasses the
            # watermark so an oversized-but-feasible prompt can't starve
            headroom = watermark if (any_resident or admit) else 0
            if need > free_blocks - headroom:
                if self.skip_ahead:
                    continue
                break
            admit.append(req)
            free_blocks -= need
        return admit

    def planned_prefill_remaining(self, view: SchedulerView,
                                  req: Request) -> int:
        """``prefill_remaining`` as it will stand after this iteration's
        placement: a request admitted from the queue may start past
        context 0 via a prefix-cache hit (the engine seeds its block
        table from the cache in ``_place``), so only the uncached tail
        needs token budget. Read-only probe; without prefix caching this
        is exactly ``prefill_remaining``."""
        rem = req.prefill_remaining
        if (req.state is ReqState.WAITING and req.context_len == 0
                and req.kv_payload is None and req.input_len > 1
                and getattr(view.allocator, "prefix_cache", False)):
            rem -= view.allocator.lookup_prefix(
                req.prompt, max_tokens=req.input_len - 1)
        return max(rem, 0)

    def pack_prefill(self, view: SchedulerView, residents: List[Request],
                     decode: List[Request]) -> List[PrefillChunk]:
        """Fill the token budget left by decodes with prefill chunks —
        one request (fcfs) or several (sarathi/sjf)."""
        if view.cfg.decode_only:
            return []
        budget = view.cfg.max_batched_tokens - len(decode)
        cands = [r for r in residents
                 if effective_state(r) is ReqState.PREFILL]
        chunks: List[PrefillChunk] = []
        for r in self.prefill_order(cands):
            if budget <= 0:
                break
            n = min(self.planned_prefill_remaining(view, r), budget)
            if n <= 0:
                continue
            chunks.append(PrefillChunk(r, n))
            budget -= n
            if (self.max_prefill_seqs is not None
                    and len(chunks) >= self.max_prefill_seqs):
                break
        return chunks

    def _preempt_for_growth(self, view: SchedulerView,
                            running: List[Request]) -> List[Request]:
        """When the decoders' next-token KV growth no longer fits, free
        low-priority requests (recompute) until the survivors fit.
        Mid-prefill residents are the cheapest victims (no generated
        tokens to recompute) and go first; then decoders in policy order.
        The highest-priority decoder is never preempted."""
        alloc = view.allocator
        extra = {r.req_id: max(0, alloc.blocks_needed(r.total_ctx)
                               - alloc.owned_blocks(r.req_id))
                 for r in running}
        total_extra = sum(extra.values())
        free = alloc.num_free
        if total_extra <= free:
            return []
        prefilling = [r for r in view.residents()
                      if effective_state(r) is ReqState.PREFILL]
        # first victim first; [:-1] protects the highest-priority decoder
        pool = (self.victim_order(prefilling)
                + self.victim_order(running)[:-1])
        victims: List[Request] = []
        for v in pool:
            if total_extra <= free:
                break
            victims.append(v)
            free += alloc.owned_blocks(v.req_id)
            total_extra -= extra.get(v.req_id, 0)
        return victims

    # ------------------------------------------------------------------
    # engine probes (runnable / idle-jump)
    # ------------------------------------------------------------------
    def has_admissible(self, view: SchedulerView) -> bool:
        """Would a step make admission progress right now? (Consulted by
        ``Engine.runnable`` only when no request is resident.)"""
        return bool(self.select_admissions(view))

    def next_ready_time(self, view: SchedulerView) -> Optional[float]:
        """Earliest queued ready_time an idle engine should jump to.

        Only *future* times count: this is consulted when the engine is
        idle and ``has_admissible`` said no, so a request that is already
        ready yet still inadmissible (oversized for the pool) can never
        become admissible by jumping the clock — reporting its past
        timestamp would freeze the cluster loop in a no-op-jump livelock.
        """
        cands = (view.queue if self.skip_ahead
                 else [view.queue[0]] if view.queue else [])
        future = [r.ready_time for r in cands if r.ready_time > view.clock]
        return min(future) if future else None
