"""Observability: structured tracing and Perfetto export (docs/OBSERVABILITY.md)."""
from repro.obs.tracer import Tracer

__all__ = ["Tracer"]
