"""Structured tracing over simulated clocks — the cluster's flight
recorder.

One :class:`Tracer` records the full life of every request as typed
events over the simulation's deterministic clocks: spans (``complete``),
instants, gauge counters, flow arrows tying a KV transfer's send to its
receive, and async request lifelines spanning submit → finish/cancel.
Events are stored as Chrome ``trace_event`` dicts (the format Perfetto
and ``chrome://tracing`` load directly), in **emission order** — the
emission sequence itself is the determinism artifact: same spec + seed
⇒ the same ``events`` list, so traces are CI-diffable.

Track model (how the timeline renders):

* one Chrome *process* per endpoint (``pid``), one *thread* per engine
  (``tid``) — a Cronus pair shows its PPI and CPI as two lanes under one
  endpoint group, a worker shows a single ``main`` lane;
* process 0 is the synthetic ``cluster`` process whose ``control`` lane
  carries cluster-scope instants (submit, route decisions, balancer
  splits, autoscale actions, attach/detach) and the cumulative transfer
  counters.

Track handles are small ints from :meth:`track`; the string form
``"endpoint/engine"`` (the :class:`~repro.kvcache.transfer
.TransferEngine`'s pool names) resolves through :meth:`track_for`, so
flow arrows land on the same lanes the iteration spans live on.

The hot-path contract, matching the repo's other opt-in surfaces: the
tracer is only ever reached behind ``if tracer is not None`` guards, so
with tracing off no event dict — not one — is allocated, and every
aggregate metric dict stays byte-identical to an untraced run.

Timestamps are float microseconds (``sim_seconds * 1e6``), the unit
Chrome expects; the µs↔s round-trip error is ~1e-16 relative, far
inside the 1e-6 tolerance ``tools/trace_report.py`` cross-checks
against ``aggregate()``.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Tuple


class Tracer:
    """Event recorder for one cluster run. Obtain via
    :meth:`repro.serving.api.InferenceService.start_trace`."""

    def __init__(self):
        # emission-order event list: THE determinism artifact (tests
        # compare two runs' lists for equality)
        self.events: List[dict] = []
        self._meta: List[dict] = []                 # chrome "M" events
        self._procs: Dict[str, int] = {}            # process name -> pid
        self._next_tid: Dict[int, int] = {}         # pid -> next tid
        self._by_key: Dict[Tuple[str, str], int] = {}
        self._tracks: List[Tuple[int, int]] = []    # handle -> (pid, tid)
        self._flow_seq = 0
        # process 0 / thread 0: cluster-scope control lane
        self.control = self.track("cluster", "control")

    # ------------------------------------------------------------------
    # tracks
    # ------------------------------------------------------------------
    def track(self, process: str, thread: str = "main") -> int:
        """Handle for the (process, thread) lane, creating it (and its
        Perfetto naming metadata) on first use."""
        key = (process, thread)
        handle = self._by_key.get(key)
        if handle is not None:
            return handle
        pid = self._procs.get(process)
        if pid is None:
            pid = len(self._procs)
            self._procs[process] = pid
            self._meta.append({"ph": "M", "name": "process_name",
                               "pid": pid, "tid": 0,
                               "args": {"name": process}})
        tid = self._next_tid.get(pid, 0)
        self._next_tid[pid] = tid + 1
        self._meta.append({"ph": "M", "name": "thread_name",
                           "pid": pid, "tid": tid,
                           "args": {"name": thread}})
        handle = len(self._tracks)
        self._tracks.append((pid, tid))
        self._by_key[key] = handle
        return handle

    def track_for(self, name: str) -> int:
        """Resolve a transfer-engine pool name (``"endpoint"`` or
        ``"endpoint/engine"``) to a track, creating it lazily — a
        migration's source may be an endpoint that was never registered
        as an engine lane (or already detached)."""
        process, sep, thread = name.partition("/")
        return self.track(process, thread if sep else "main")

    # ------------------------------------------------------------------
    # emitters (t in simulated seconds)
    # ------------------------------------------------------------------
    def complete(self, track: int, name: str, t0: float, t1: float,
                 args: Optional[dict] = None, cat: str = "span") -> None:
        """A span [t0, t1] on ``track`` (chrome ``X``)."""
        pid, tid = self._tracks[track]
        ev = {"ph": "X", "name": name, "cat": cat, "pid": pid, "tid": tid,
              "ts": t0 * 1e6, "dur": (t1 - t0) * 1e6}
        if args is not None:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, track: int, name: str, t: float,
                args: Optional[dict] = None, cat: str = "event") -> None:
        """A point event at ``t`` (chrome ``i``, thread-scoped)."""
        pid, tid = self._tracks[track]
        ev = {"ph": "i", "name": name, "cat": cat, "pid": pid, "tid": tid,
              "ts": t * 1e6, "s": "t"}
        if args is not None:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, track: int, name: str, t: float,
                values: Dict[str, float]) -> None:
        """Gauge sample(s) at ``t`` (chrome ``C``); each key renders as
        one series under the counter ``name``."""
        pid, tid = self._tracks[track]
        self.events.append({"ph": "C", "name": name, "cat": "counter",
                            "pid": pid, "tid": tid, "ts": t * 1e6,
                            "args": values})

    def new_flow_id(self) -> int:
        """Fresh id tying one flow's start to its end."""
        self._flow_seq += 1
        return self._flow_seq

    def flow_start(self, track: int, name: str, t: float, flow_id: int,
                   args: Optional[dict] = None) -> None:
        """Tail of a flow arrow (chrome ``s``) — e.g. a KV send."""
        pid, tid = self._tracks[track]
        ev = {"ph": "s", "name": name, "cat": "flow", "id": flow_id,
              "pid": pid, "tid": tid, "ts": t * 1e6}
        if args is not None:
            ev["args"] = args
        self.events.append(ev)

    def flow_end(self, track: int, name: str, t: float, flow_id: int,
                 args: Optional[dict] = None) -> None:
        """Head of a flow arrow (chrome ``f``, binding-point enclosing)
        — e.g. the matching KV receive."""
        pid, tid = self._tracks[track]
        ev = {"ph": "f", "name": name, "cat": "flow", "id": flow_id,
              "bp": "e", "pid": pid, "tid": tid, "ts": t * 1e6}
        if args is not None:
            ev["args"] = args
        self.events.append(ev)

    def async_begin(self, track: int, name: str, t: float, ident: str,
                    args: Optional[dict] = None,
                    cat: str = "request") -> None:
        """Open an async lifeline (chrome ``b``) keyed by (cat, id) —
        one per request, submit → finish/cancel."""
        pid, tid = self._tracks[track]
        ev = {"ph": "b", "name": name, "cat": cat, "id": ident,
              "pid": pid, "tid": tid, "ts": t * 1e6}
        if args is not None:
            ev["args"] = args
        self.events.append(ev)

    def async_end(self, track: int, name: str, t: float, ident: str,
                  args: Optional[dict] = None,
                  cat: str = "request") -> None:
        """Close the matching async lifeline (chrome ``e``)."""
        pid, tid = self._tracks[track]
        ev = {"ph": "e", "name": name, "cat": cat, "id": ident,
              "pid": pid, "tid": tid, "ts": t * 1e6}
        if args is not None:
            ev["args"] = args
        self.events.append(ev)

    # ------------------------------------------------------------------
    # export
    # ------------------------------------------------------------------
    def to_chrome(self) -> List[dict]:
        """The trace as a Chrome ``trace_event`` list: naming metadata
        first, then every event stably sorted by timestamp (stable, so
        same-instant events keep their causal emission order — e.g. a
        CPI's TTFT overwrite stays after the PPI timestamp it
        supersedes)."""
        return self._meta + sorted(self.events, key=lambda e: e["ts"])

    def export(self, path: str) -> None:
        """Write Perfetto-loadable JSON (`ui.perfetto.dev` → Open trace
        file, or ``chrome://tracing``)."""
        with open(path, "w") as f:
            json.dump({"traceEvents": self.to_chrome(),
                       "displayTimeUnit": "ms"}, f)
