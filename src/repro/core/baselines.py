"""DP+chunked and PP+chunked baselines (paper §3.2-3.3, §5.1).

Disaggregated H-L / L-H live in cronus.py (they reuse the Cronus code with a
pinned partial length, exactly as the paper's evaluation does).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

from repro.cluster.router import RoundRobinRouter
from repro.cluster.runtime import ClusterRuntime, WorkerEndpoint
from repro.core.engine import Engine, EngineConfig
from repro.core.request import Request
from repro.serving.hardware import (DeviceModel, DeviceSpec, active_param_bytes,
                                    attn_flops, kv_bytes_per_token,
                                    matmul_flops_per_token, param_bytes,
                                    transfer_bytes)


# ---------------------------------------------------------------------------
# DP + chunked prefill
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DPSystem:
    """Weighted round-robin dispatch over independent engines.

    Paper §5.1: weight 3 for the A100, 1 for the A10/A30; waiting-queue caps
    3 and 1; chunk size 512 on the high-end engine, 256 on the low-end."""
    engines: List[Engine]
    weights: List[int]
    queue_caps: List[int]

    def endpoints(self) -> List[WorkerEndpoint]:
        return [WorkerEndpoint(e.name, e, queue_cap=cap)
                for e, cap in zip(self.engines, self.queue_caps)]

    def run(self, requests: List[Request], max_steps: int = 10_000_000):
        # ready_time (set by WorkerEndpoint.submit) keeps engines from
        # running future arrivals early, so eager weighted-RR dispatch into
        # the shared cluster loop matches the old private loop exactly
        runtime = ClusterRuntime(self.endpoints(),
                                 RoundRobinRouter(weights=self.weights))
        return runtime.run(requests, max_steps)


def build_dp(cfg, hi_device: DeviceModel, lo_device: DeviceModel, *,
             executor_factory: Callable, max_slots: int = 64,
             block_size: int = 16, sched_policy: str = "fcfs",
             prefix_cache: bool = False,
             num_kv_blocks: Optional[int] = None,
             host_kv_blocks: int = 0,
             executor: str = "null") -> DPSystem:
    hi = Engine("dp-hi", cfg,
                EngineConfig(max_batched_tokens=512, max_slots=max_slots,
                             block_size=block_size,
                             num_kv_blocks=(num_kv_blocks if num_kv_blocks
                                            is not None else
                                            max(hi_device.kv_block_budget(block_size), 64)),
                             sched_policy=sched_policy,
                             prefix_cache=prefix_cache,
                             host_kv_blocks=host_kv_blocks,
                             executor=executor),
                hi_device, executor_factory("hi"))
    lo = Engine("dp-lo", cfg,
                EngineConfig(max_batched_tokens=256, max_slots=max_slots,
                             block_size=block_size,
                             num_kv_blocks=(num_kv_blocks if num_kv_blocks
                                            is not None else
                                            max(lo_device.kv_block_budget(block_size), 64)),
                             sched_policy=sched_policy,
                             prefix_cache=prefix_cache,
                             host_kv_blocks=host_kv_blocks,
                             executor=executor),
                lo_device, executor_factory("lo"))
    return DPSystem(engines=[hi, lo], weights=[3, 1], queue_caps=[3, 1])


# ---------------------------------------------------------------------------
# PP + chunked prefill
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PipelineDeviceModel:
    """Two-stage heterogeneous pipeline: layers split by BF16 FLOPS (paper
    §5.1). vLLM-0.6.1-era PP executes a batch's stages synchronously (no
    microbatch overlap within one engine step), so an iteration costs the
    SUM of stage times plus the inter-stage activation transfer — incurred
    per chunk during prefill and per token during decode, the accumulated
    overhead of §3.3."""
    hi: DeviceSpec
    lo: DeviceSpec
    cfg: object

    @property
    def frac_hi(self) -> float:
        return self.hi.flops / (self.hi.flops + self.lo.flops)

    def _stage_time(self, spec: DeviceSpec, frac: float, flops: float,
                    bytes_: float) -> float:
        t_c = frac * flops / (spec.flops * spec.flops_eff)
        t_m = frac * bytes_ / (spec.hbm_bw * spec.bw_eff)
        return max(t_c, t_m) + spec.overhead

    def chunked_iter_time(self, prefill_tokens: int, prefill_ctx: int,
                          decode_ctx_sum: float, n_decode: int) -> float:
        new = prefill_tokens + n_decode
        f = matmul_flops_per_token(self.cfg) * new \
            + attn_flops(self.cfg, prefill_tokens,
                         prefill_ctx + prefill_tokens / 2.0) \
            + attn_flops(self.cfg, 1, decode_ctx_sum)
        by = active_param_bytes(self.cfg) \
            + kv_bytes_per_token(self.cfg) * (
                prefill_ctx + prefill_tokens + decode_ctx_sum + new)
        stage = (self._stage_time(self.hi, self.frac_hi, f, by)
                 + self._stage_time(self.lo, 1 - self.frac_hi, f, by))
        comm = max(new, 1) * self.cfg.d_model * 2.0 / self.hi.link_bw
        return stage + comm

    def decode_iter_time(self, decode_ctx_sum: float, n_decode: int) -> float:
        return self.chunked_iter_time(0, 0, decode_ctx_sum, n_decode)

    def prefill_time(self, n_tokens: int, ctx_start: int = 0) -> float:
        return self.chunked_iter_time(n_tokens, ctx_start, 0.0, 0)

    def transfer_time(self, n_tokens: int) -> float:
        return 0.0

    def host_kv_time(self, n_tokens: int) -> float:
        # both stages share the hi host's PCIe attach for the modeled tier
        return transfer_bytes(self.cfg, n_tokens) / self.hi.pcie_bw

    def kv_block_budget(self, block_size: int, mem_frac: float = 0.9) -> int:
        """Each stage holds its fraction of layers' KV; capacity is the min
        over stages (paper §3.3: reduced effective batch size)."""
        per_tok = kv_bytes_per_token(self.cfg)
        if per_tok <= 0:
            return 1_000_000
        caps = []
        for spec, frac in ((self.hi, self.frac_hi), (self.lo, 1 - self.frac_hi)):
            free = spec.hbm_cap * mem_frac - frac * param_bytes(self.cfg)
            caps.append(free / (per_tok * frac * block_size))
        return max(int(min(caps)), 0)


@dataclasses.dataclass
class PPSystem:
    engine: Engine

    def run(self, requests: List[Request], max_steps: int = 10_000_000):
        # single unbounded endpoint: FCFS into the one fused-pipeline engine
        runtime = ClusterRuntime(
            [WorkerEndpoint(self.engine.name, self.engine, queue_cap=None)],
            RoundRobinRouter())
        return runtime.run(requests, max_steps)


def build_pp(cfg, hi_spec: DeviceSpec, lo_spec: DeviceSpec, *,
             executor_factory: Callable, max_slots: int = 64,
             block_size: int = 16, sched_policy: str = "fcfs",
             prefix_cache: bool = False,
             num_kv_blocks: Optional[int] = None,
             host_kv_blocks: int = 0,
             executor: str = "null") -> PPSystem:
    device = PipelineDeviceModel(hi_spec, lo_spec, cfg)
    eng = Engine("pp", cfg,
                 EngineConfig(max_batched_tokens=512, max_slots=max_slots,
                              block_size=block_size,
                              num_kv_blocks=(num_kv_blocks if num_kv_blocks
                                             is not None else
                                             max(device.kv_block_budget(block_size), 64)),
                              sched_policy=sched_policy,
                              prefix_cache=prefix_cache,
                              host_kv_blocks=host_kv_blocks,
                              executor=executor),
                 device, executor_factory("pp"))
    return PPSystem(engine=eng)
