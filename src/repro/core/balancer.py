"""The Balancer (paper §4.3 + Appendix A, Algorithm 1).

Splits each incoming request's prefill between the PPI (low-end device) and
the CPI (high-end device) such that the *predicted* partial-prefill time on
the PPI equals the predicted total chunked-prefill time of the remainder on
the CPI — equal stage throughput <=> both devices saturated.

Implementation follows Algorithm 1 line by line:
  * if the CPI lacks free KV blocks for the whole prompt, the entire prompt
    is prefilled on the PPI (partial length = L_in);
  * otherwise 512 candidate split points are scored with Eq. 2 / Eq. 1+3 and
    the argmin of |T_parprefill - T_chunked| wins.

Note: Algorithm 1 as printed estimates the mean chunked context as
(L_in + L_last)/2; Eq. 1 (arithmetic-series sum of per-iteration context,
first context = L_p) implies (L_p + L_last)/2. We default to the printed
algorithm and expose ``eq1_mean`` to switch — the difference is small since
L_last is within one chunk of L_in.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.predictor import ChunkedIterPredictor, PrefillPredictor


@dataclasses.dataclass
class CPIStats:
    """Statistics pulled from the chunked prefill instance (step (1))."""
    n_decode: int            # number of decode requests resident in the CPI
    decode_ctx_sum: float    # sum of their context lengths (L_ctxd)
    free_kv_blocks: int      # N_free
    block_size: int          # N_size
    max_batched_tokens: int  # B


@dataclasses.dataclass
class Balancer:
    prefill_pred: PrefillPredictor
    chunked_pred: ChunkedIterPredictor
    n_candidates: int = 512
    eq1_mean: bool = False

    def partial_prefill_length(self, l_in: int, stats: CPIStats) -> int:
        """Algorithm 1: choose the partial prefill length for a request."""
        if l_in <= 1:
            return l_in
        # Not enough free KV blocks on the CPI -> prefill entirely on the PPI.
        if stats.free_kv_blocks < math.ceil(l_in / stats.block_size):
            return l_in

        n = self.n_candidates
        l_p = np.ceil(np.arange(1, n + 1) / n * l_in)          # candidates
        t_prefill = self.prefill_pred.predict(l_p)             # Eq. 2

        n_p = max(stats.max_batched_tokens - stats.n_decode, 1)  # prefill tokens/iter
        l_c = l_in - l_p                                        # remainder on CPI
        n_iter = np.ceil(l_c / n_p)
        l_last = l_p + np.floor(l_c / n_p) * n_p                # last-iter context
        first_ctx = l_p if self.eq1_mean else float(l_in)
        mean_ctx = (first_ctx + l_last) / 2.0
        t_chunked = n_iter * self.chunked_pred.predict(mean_ctx,
                                                       stats.decode_ctx_sum)
        idx = int(np.argmin(np.abs(t_prefill - t_chunked)))
        return int(l_p[idx])
