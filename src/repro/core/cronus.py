"""Cronus orchestrator (paper §4.2, Fig. 1-2) + the disaggregated baselines.

Topology: frontend (with the Balancer) -> PPI (partial prefill instance,
low-end device, prefill-only) -> KV buffer -> CPI (chunked prefill instance,
high-end device, chunked prefill + all decode).

Protocol per request R_i (paper numbering):
  (1) at dispatch the Balancer pulls CPI stats,
  (2) computes the partial prefill length L_p,
  (3) dispatches R_i[:L_p] to the PPI (PPI holds <= 2 requests),
  (4) PPI completion stores KV in the buffer and notifies the frontend,
  (5) frontend forwards R_i (with partial_len) to the CPI,
  (6-7) the CPI's first iteration for R_i ingests the KV transfer, overlapped
        with other requests' decode/chunked-prefill compute,
  then standard chunked prefill + decode on the CPI.

The disaggregated baselines reuse this code verbatim with the partial
length pinned to L_in (paper §5.1: "the same code as our partial prefill
implementation, but always set the partial prefill length to the input
length"), and the CPI flipped to decode-only. High->Low swaps the devices.

Time is simulated (engines carry local clocks advanced by the device
roofline model); compute is real or null depending on the executor.
"""
from __future__ import annotations

import copy
import dataclasses
from collections import deque
from typing import Callable, List, Optional

from repro.core.balancer import Balancer
from repro.core.engine import Engine, EngineConfig
from repro.core.metrics import aggregate
from repro.core.request import ReqState, Request


class FixedBalancer:
    """Disaggregated baselines: partial prefill length == input length."""

    def partial_prefill_length(self, l_in: int, stats) -> int:
        return l_in


@dataclasses.dataclass
class CronusSystem:
    ppi: Engine                      # prefill-only, low-end device
    cpi: Engine                      # chunked prefill + decode, high-end
    balancer: object                 # Balancer | FixedBalancer
    max_ppi_requests: int = 2        # paper: at most two in the PPI
    # Decode offload (paper §6 "future work", implemented here): when the
    # CPI lacks KV blocks for a request (Alg. 1's fallback case — the
    # decode-bound regime of short-input/long-output traces), the request
    # completes ENTIRELY on the PPI: full prefill there, then decode there
    # too, with a zero-cost local "transfer". Mitigates the load imbalance
    # the paper identifies in its Limitations section.
    #
    # Policy lesson (bench_offload_limitation, first attempt REFUTED): the
    # fallback condition alone overloads the slow PPI (259/300 requests
    # offloaded -> throughput collapsed 3.4 -> 0.2 req/s, i.e. the system
    # inverted into Disagg-H-L). Offload must be bounded by the PPI's own
    # spare decode capacity — `max_offload_frac` of its KV pool.
    decode_offload: bool = False
    max_offload_frac: float = 0.5

    def run(self, requests: List[Request], max_steps: int = 10_000_000):
        arrivals = deque(sorted(requests, key=lambda r: r.arrival))
        total = len(requests)
        in_ppi = {}      # ppi view -> original
        offloaded = set()
        steps = 0

        def ppi_prefill_load():
            # offloaded decoders don't count against the paper's <=2 cap
            return len(in_ppi) + sum(
                1 for r in self.ppi.queue if r.req_id not in offloaded
                and r.req_id not in in_ppi)

        def n_done():
            return len(self.cpi.finished) + len(self.ppi.finished)

        while n_done() < total and steps < max_steps:
            steps += 1
            # ---- frontend dispatch: fill the PPI up to its cap ----------
            while arrivals and ppi_prefill_load() < self.max_ppi_requests:
                req = arrivals[0]
                if req.arrival > self.ppi.clock and ppi_prefill_load() > 0:
                    break  # PPI still busy; revisit after it advances
                arrivals.popleft()
                self.ppi.clock = max(self.ppi.clock, req.arrival)
                stats = self.cpi.stats()                       # step (1)
                l_p = self.balancer.partial_prefill_length(     # step (2)
                    req.input_len, stats)
                req.partial_len = int(l_p)
                if (self.decode_offload and l_p >= req.input_len
                        and not self.balancer.__class__.__name__.startswith(
                            "Fixed")):
                    # Alg.1 fell back (CPI out of KV blocks) -> offload the
                    # whole request to the PPI (§6), but only while the PPI
                    # keeps >= (1 - max_offload_frac) of its KV pool free
                    # for its prefill duties
                    alloc = self.ppi.allocator
                    need = alloc.blocks_needed(req.input_len + req.output_len)
                    budget = int(alloc.num_blocks * self.max_offload_frac)
                    used = alloc.num_blocks - alloc.num_free
                    if used + need <= budget:
                        offloaded.add(req.req_id)
                view = copy.copy(req)                           # step (3)
                view.prompt = req.prompt[:req.partial_len]
                view.output_len = 0
                view.ready_time = req.arrival
                view.state = ReqState.WAITING
                view.context_len = 0
                in_ppi[view.req_id] = req
                self.ppi.add_request(view)

            # ---- route PPI completions (steps 4-5; offloaded stay local) --
            while self.ppi.completed_prefills:
                t_done, view = self.ppi.completed_prefills.pop(0)
                orig = in_ppi.pop(view.req_id)
                orig.partial_len = view.context_len
                orig.context_len = view.context_len
                orig.kv_payload = view.kv_payload
                orig.first_token = view.first_token
                orig.ready_time = t_done
                if orig.req_id in offloaded:
                    orig.local_payload = True       # re-inject on the PPI
                    self.ppi.add_request(orig)
                else:
                    self.cpi.add_request(orig)

            # ---- advance the lagging runnable engine ---------------------
            progressed = False
            for eng in sorted((self.ppi, self.cpi), key=lambda e: e.clock):
                if eng.runnable():
                    eng.step()
                    progressed = True
                    break
            if not progressed:
                # engines idle: jump clocks to the next event
                nexts = [t for t in (self.ppi.next_ready_time(),
                                     self.cpi.next_ready_time()) if t is not None]
                if arrivals:
                    nexts.append(arrivals[0].arrival)
                if not nexts:
                    break  # deadlock guard (shouldn't happen)
                t = min(nexts)
                self.ppi.clock = max(self.ppi.clock, t)
                self.cpi.clock = max(self.cpi.clock, t)

        return aggregate([r.metrics for r in self.cpi.finished])


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------

def build_cronus(cfg, ppi_device, cpi_device, *, executor_factory: Callable,
                 balancer: Optional[object] = None,
                 max_batched_tokens: int = 512,
                 max_slots: int = 64, block_size: int = 16,
                 decode_only_cpi: bool = False,
                 decode_offload: bool = False) -> CronusSystem:
    """executor_factory(role: str) -> executor ('ppi' | 'cpi')."""
    ppi_blocks = max(ppi_device.kv_block_budget(block_size), 64)
    cpi_blocks = max(cpi_device.kv_block_budget(block_size), 64)
    ppi = Engine("ppi", cfg,
                 EngineConfig(max_batched_tokens=max_batched_tokens,
                              max_slots=max_slots if decode_offload else 2,
                              block_size=block_size,
                              num_kv_blocks=ppi_blocks, prefill_only=True),
                 ppi_device, executor_factory("ppi"))
    cpi = Engine("cpi", cfg,
                 EngineConfig(max_batched_tokens=max_batched_tokens,
                              max_slots=max_slots, block_size=block_size,
                              num_kv_blocks=cpi_blocks,
                              decode_only=decode_only_cpi),
                 cpi_device, executor_factory("cpi"))
    return CronusSystem(ppi=ppi, cpi=cpi,
                        balancer=balancer if balancer is not None
                        else FixedBalancer(),
                        decode_offload=decode_offload)


def build_disaggregated(cfg, prefill_device, decode_device, *,
                        executor_factory: Callable, **kw) -> CronusSystem:
    """Disagg L-H: prefill_device=low / decode_device=high; H-L swapped."""
    return build_cronus(cfg, prefill_device, decode_device,
                        executor_factory=executor_factory,
                        balancer=FixedBalancer(), decode_only_cpi=True, **kw)
