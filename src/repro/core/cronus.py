"""Cronus orchestrator (paper §4.2, Fig. 1-2) + the disaggregated baselines.

Topology: frontend (with the Balancer) -> PPI (partial prefill instance,
low-end device, prefill-only) -> KV buffer -> CPI (chunked prefill instance,
high-end device, chunked prefill + all decode).

Protocol per request R_i (paper numbering):
  (1) at dispatch the Balancer pulls CPI stats,
  (2) computes the partial prefill length L_p,
  (3) dispatches R_i[:L_p] to the PPI (PPI holds <= 2 requests),
  (4) PPI completion stores KV in the buffer and notifies the frontend,
  (5) frontend forwards R_i (with partial_len) to the CPI,
  (6-7) the CPI's first iteration for R_i ingests the KV transfer, overlapped
        with other requests' decode/chunked-prefill compute,
  then standard chunked prefill + decode on the CPI.

The disaggregated baselines reuse this code verbatim with the partial
length pinned to L_in (paper §5.1: "the same code as our partial prefill
implementation, but always set the partial prefill length to the input
length"), and the CPI flipped to decode-only. High->Low swaps the devices.

Time is simulated (engines carry local clocks advanced by the device
roofline model); compute is real or null depending on the executor.

The per-pair protocol itself lives in ``repro.cluster.pair`` (so that N
pairs can share one cluster); ``CronusSystem`` is the single-pair facade:
``run()`` wraps the pair in a one-endpoint cluster and replays the trace
through the shared event loop in ``repro.cluster.runtime``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

from typing import TYPE_CHECKING

from repro.core.engine import Engine, EngineConfig
from repro.core.request import Request

if TYPE_CHECKING:  # runtime imports are deferred: cluster.* imports core.*
    from repro.cluster.pair import CronusPairEndpoint


class FixedBalancer:
    """Disaggregated baselines: partial prefill length == input length."""

    def partial_prefill_length(self, l_in: int, stats) -> int:
        return l_in


@dataclasses.dataclass
class CronusSystem:
    ppi: Engine                      # prefill-only, low-end device
    cpi: Engine                      # chunked prefill + decode, high-end
    balancer: object                 # Balancer | FixedBalancer
    max_ppi_requests: int = 2        # paper: at most two in the PPI
    # Decode offload (paper §6 "future work", implemented here): when the
    # CPI lacks KV blocks for a request (Alg. 1's fallback case — the
    # decode-bound regime of short-input/long-output traces), the request
    # completes ENTIRELY on the PPI: full prefill there, then decode there
    # too, with a zero-cost local "transfer". Mitigates the load imbalance
    # the paper identifies in its Limitations section.
    #
    # Policy lesson (bench_offload_limitation, first attempt REFUTED): the
    # fallback condition alone overloads the slow PPI (259/300 requests
    # offloaded -> throughput collapsed 3.4 -> 0.2 req/s, i.e. the system
    # inverted into Disagg-H-L). Offload must be bounded by the PPI's own
    # spare decode capacity — `max_offload_frac` of its KV pool.
    decode_offload: bool = False
    max_offload_frac: float = 0.5

    def endpoint(self, name: str = "cronus") -> "CronusPairEndpoint":
        """This pair as a routable cluster endpoint (fresh handoff state)."""
        from repro.cluster.pair import CronusPairEndpoint
        return CronusPairEndpoint(
            name, self.ppi, self.cpi, self.balancer,
            max_ppi_requests=self.max_ppi_requests,
            decode_offload=self.decode_offload,
            max_offload_frac=self.max_offload_frac)

    def run(self, requests: List[Request], max_steps: int = 10_000_000):
        from repro.cluster.router import RoundRobinRouter
        from repro.cluster.runtime import ClusterRuntime
        # Aggregates over BOTH engines: under decode_offload requests that
        # complete on the PPI count too (they were silently dropped before).
        return ClusterRuntime([self.endpoint()], RoundRobinRouter()).run(
            requests, max_steps)


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------

def build_cronus(cfg, ppi_device, cpi_device, *, executor_factory: Callable,
                 balancer: Optional[object] = None,
                 max_batched_tokens: int = 512,
                 max_slots: int = 64, block_size: int = 16,
                 decode_only_cpi: bool = False,
                 decode_offload: bool = False,
                 sched_policy: str = "fcfs",
                 prefix_cache: bool = False,
                 num_kv_blocks: Optional[int] = None,
                 host_kv_blocks: int = 0,
                 executor: str = "null") -> CronusSystem:
    """executor_factory(role: str) -> executor ('ppi' | 'cpi').

    ``sched_policy`` selects the iteration-level batch-composition policy
    (``repro.scheduling.SCHEDULERS``) for BOTH engines of the pair; the
    default ``fcfs`` reproduces the seed engine bit-for-bit.
    ``prefix_cache`` enables shared-prefix KV reuse on both engines: a
    hit on the PPI shortens its split-prefill portion, a hit on the CPI
    shortens the chunked remainder. ``num_kv_blocks`` overrides the
    device-HBM-derived KV pool size on both engines — required for the
    paged executor, which materializes the pool for real; ``executor``
    records the compute backend in each EngineConfig. ``host_kv_blocks``
    adds a host-memory cache tier of that many blocks to both engines
    (requires ``prefix_cache``): refcount-0 prefix blocks demote to host
    DRAM instead of being dropped, and promote back on a hit, with the
    PCIe cost charged into each engine's iteration time."""
    ppi_blocks = (num_kv_blocks if num_kv_blocks is not None
                  else max(ppi_device.kv_block_budget(block_size), 64))
    cpi_blocks = (num_kv_blocks if num_kv_blocks is not None
                  else max(cpi_device.kv_block_budget(block_size), 64))
    ppi = Engine("ppi", cfg,
                 EngineConfig(max_batched_tokens=max_batched_tokens,
                              max_slots=max_slots if decode_offload else 2,
                              block_size=block_size,
                              num_kv_blocks=ppi_blocks, prefill_only=True,
                              sched_policy=sched_policy,
                              prefix_cache=prefix_cache,
                              host_kv_blocks=host_kv_blocks,
                              executor=executor),
                 ppi_device, executor_factory("ppi"))
    cpi = Engine("cpi", cfg,
                 EngineConfig(max_batched_tokens=max_batched_tokens,
                              max_slots=max_slots, block_size=block_size,
                              num_kv_blocks=cpi_blocks,
                              decode_only=decode_only_cpi,
                              sched_policy=sched_policy,
                              prefix_cache=prefix_cache,
                              host_kv_blocks=host_kv_blocks,
                              executor=executor),
                 cpi_device, executor_factory("cpi"))
    return CronusSystem(ppi=ppi, cpi=cpi,
                        balancer=balancer if balancer is not None
                        else FixedBalancer(),
                        decode_offload=decode_offload)


def build_disaggregated(cfg, prefill_device, decode_device, *,
                        executor_factory: Callable, **kw) -> CronusSystem:
    """Disagg L-H: prefill_device=low / decode_device=high; H-L swapped."""
    return build_cronus(cfg, prefill_device, decode_device,
                        executor_factory=executor_factory,
                        balancer=FixedBalancer(), decode_only_cpi=True, **kw)
