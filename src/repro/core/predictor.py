"""Execution-time predictors (paper §4.4, Eq. 2–3), fitted by linear
regression on profiled data — exactly the paper's methodology. Profiles come
either from the roofline cost model (simulator) or from measured wall times
(real engine on CPU); the balancer is agnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


def _fit_stats(y, yhat):
    y, yhat = np.asarray(y, float), np.asarray(yhat, float)
    ss_res = float(np.sum((y - yhat) ** 2))
    ss_tot = float(np.sum((y - y.mean()) ** 2))
    r2 = 1.0 - ss_res / ss_tot if ss_tot > 0 else 1.0
    mape = float(np.mean(np.abs((y - yhat) / np.maximum(np.abs(y), 1e-12))))
    return r2, mape


@dataclasses.dataclass
class PrefillPredictor:
    """Eq. 2: T_parprefill(L) = k_p * L + b_p."""
    k_p: float = 0.0
    b_p: float = 0.0
    r2: float = float("nan")
    mape: float = float("nan")

    def fit(self, lengths: Sequence[float], times: Sequence[float]):
        x = np.asarray(lengths, float)
        y = np.asarray(times, float)
        a = np.stack([x, np.ones_like(x)], axis=1)
        (self.k_p, self.b_p), *_ = np.linalg.lstsq(a, y, rcond=None)
        self.r2, self.mape = _fit_stats(y, a @ np.array([self.k_p, self.b_p]))
        return self

    def predict(self, length):
        return self.k_p * np.asarray(length, float) + self.b_p


@dataclasses.dataclass
class ChunkedIterPredictor:
    """Eq. 3: t_chunked = k_ctxp * L(P2 ctx) + k_ctxd * sum L(decode ctx) + b_c.

    The number of prefill tokens per iteration is absorbed into b_c (paper:
    "approximately equal to the maximum number of batched tokens")."""
    k_ctxp: float = 0.0
    k_ctxd: float = 0.0
    b_c: float = 0.0
    r2: float = float("nan")
    mape: float = float("nan")

    def fit(self, prefill_ctx: Sequence[float], decode_ctx_sum: Sequence[float],
            times: Sequence[float]):
        x1 = np.asarray(prefill_ctx, float)
        x2 = np.asarray(decode_ctx_sum, float)
        y = np.asarray(times, float)
        a = np.stack([x1, x2, np.ones_like(x1)], axis=1)
        coef, *_ = np.linalg.lstsq(a, y, rcond=None)
        self.k_ctxp, self.k_ctxd, self.b_c = map(float, coef)
        self.r2, self.mape = _fit_stats(y, a @ coef)
        return self

    def predict(self, prefill_ctx, decode_ctx_sum):
        return (self.k_ctxp * np.asarray(prefill_ctx, float)
                + self.k_ctxd * np.asarray(decode_ctx_sum, float) + self.b_c)


def profile_prefill(device_model, lengths=None) -> PrefillPredictor:
    """Profile partial-prefill times on a device model and fit Eq. 2."""
    lengths = lengths if lengths is not None else np.linspace(64, 8192, 40)
    times = [device_model.prefill_time(int(n)) for n in lengths]
    return PrefillPredictor().fit(lengths, times)


def profile_chunked(device_model, chunk_size: int = 512,
                    ctx_grid=None, dctx_grid=None) -> ChunkedIterPredictor:
    """Profile chunked-prefill iteration times and fit Eq. 3 (paper Fig. 3)."""
    ctx_grid = ctx_grid if ctx_grid is not None else np.linspace(0, 16384, 24)
    dctx_grid = dctx_grid if dctx_grid is not None else np.linspace(0, 65536, 12)
    xs1, xs2, ys = [], [], []
    for ctx in ctx_grid:
        for dctx in dctx_grid:
            n_d = max(int(dctx / 1200), 0)       # plausible decode batch size
            xs1.append(ctx)
            xs2.append(dctx)
            ys.append(device_model.chunked_iter_time(
                max(chunk_size - n_d, 1), int(ctx), dctx, n_d))
    return ChunkedIterPredictor().fit(xs1, xs2, ys)


def profile_prefill_measured(fn, lengths) -> PrefillPredictor:
    """Fit Eq. 2 on measured wall times: fn(length)->seconds."""
    times = [fn(int(n)) for n in lengths]
    return PrefillPredictor().fit(list(lengths), times)
