"""Continuous-batching inference engine with chunked prefill (vLLM-class).

One ``Engine`` models one serving instance (one device or pod slice). Each
``step()`` executes a single iteration: all RUNNING requests decode one
token, and (if token budget remains) the head PREFILL request advances by a
chunk — the Sarathi/vLLM piggybacking the paper builds on. Iteration
duration comes from the device's roofline model (simulated time); compute
correctness comes from the pluggable executor (real JAX or null).

The engine doubles as:
  * the CPI (chunked prefill instance) of Cronus — requests arrive with
    ``partial_len`` set and a KV payload to ingest,
  * a standalone DP worker (chunked prefill + decode),
  * a decode-only / prefill-only instance for the disaggregated baselines
    (via ``prefill_only`` / ``decode_only``).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional

from repro.core.balancer import CPIStats
from repro.core.request import ReqState, Request
from repro.kvcache import BlockAllocator


@dataclasses.dataclass
class EngineConfig:
    max_batched_tokens: int = 512      # chunked-prefill token budget B
    max_slots: int = 64                # resident request limit
    block_size: int = 16               # KV block granularity N_size
    num_kv_blocks: int = 4096          # KV pool size (from device HBM budget)
    prefill_only: bool = False         # disaggregated prefill instance
    decode_only: bool = False          # disaggregated decode instance


class Engine:
    def __init__(self, name: str, cfg, engine_cfg: EngineConfig, device_model,
                 executor):
        self.name = name
        self.cfg = cfg
        self.ecfg = engine_cfg
        self.device = device_model
        self.executor = executor
        self.clock = 0.0
        self.allocator = BlockAllocator(engine_cfg.num_kv_blocks,
                                        engine_cfg.block_size)
        self.slots: List[Optional[Request]] = [None] * engine_cfg.max_slots
        self.queue: Deque[Request] = deque()
        self.finished: List[Request] = []
        self.completed_prefills: List = []   # (time, req) from prefill-only role

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def add_request(self, req: Request, now: Optional[float] = None):
        if now is not None:
            self.clock = max(self.clock, now)
        req.state = ReqState.WAITING
        self.queue.append(req)

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slots):
            if r is None:
                return i
        return None

    def _admit(self):
        while self.queue:
            req = self.queue[0]
            if req.ready_time > self.clock:
                return  # FCFS: head not yet ready (in transit from the PPI)
            slot = self._free_slot()
            if slot is None:
                return
            # conservative: reserve blocks for the full final context
            need = req.input_len + req.output_len
            if not self.allocator.can_allocate(need):
                return
            self.queue.popleft()
            self.allocator.allocate(req.req_id, need)
            req.slot = slot
            self.slots[slot] = req
            self.executor.reset_slot(slot)
            if req.kv_payload is not None:
                req.state = ReqState.TRANSFER       # ingest during next iter
            elif req.context_len >= req.input_len:
                req.state = ReqState.RUNNING         # pre-prefilled elsewhere
            else:
                req.state = ReqState.PREFILL

    # ------------------------------------------------------------------
    # stats for the Balancer (paper step (1))
    # ------------------------------------------------------------------
    def stats(self) -> CPIStats:
        running = [r for r in self.slots if r and r.state == ReqState.RUNNING]
        return CPIStats(
            n_decode=len(running),
            decode_ctx_sum=float(sum(r.total_ctx for r in running)),
            free_kv_blocks=self.allocator.num_free,
            block_size=self.ecfg.block_size,
            max_batched_tokens=self.ecfg.max_batched_tokens,
        )

    # ------------------------------------------------------------------
    def has_work(self) -> bool:
        if self.queue and self._free_slot() is not None:
            return True
        return any(r is not None for r in self.slots)

    def runnable(self) -> bool:
        """True if step() would make progress right now."""
        if any(r is not None for r in self.slots):
            return True
        if self.queue and self._free_slot() is not None:
            req = self.queue[0]
            return (req.ready_time <= self.clock
                    and self.allocator.can_allocate(req.input_len + req.output_len))
        return False

    def next_ready_time(self) -> Optional[float]:
        """If idle but the queue head is in transit, when it becomes ready."""
        if any(r is not None for r in self.slots) or not self.queue:
            return None
        return self.queue[0].ready_time

    # ------------------------------------------------------------------
    # one iteration
    # ------------------------------------------------------------------
    def step(self) -> float:
        """Execute one iteration; returns its simulated duration (s)."""
        self._admit()

        # --- ingest pending KV transfers (overlapped with compute) -------
        transfer_time = 0.0
        ttft_at_ingest: List[Request] = []
        for r in self.slots:
            if r and r.state == ReqState.TRANSFER:
                self.executor.inject_kv(r.slot, r.kv_payload, r.context_len)
                if not r.local_payload:   # decode-offload: KV never moved
                    transfer_time = max(transfer_time,
                                        self.device.transfer_time(r.context_len))
                r.kv_payload = None
                r.state = (ReqState.RUNNING if r.context_len >= r.input_len
                           else ReqState.PREFILL)
                if r.state is ReqState.RUNNING and r.first_token is not None:
                    # fully-prefilled elsewhere (disagg / Cronus fallback):
                    # TTFT counts the KV transfer (paper §5.1 fairness rule)
                    r.generated.append(r.first_token)
                    ttft_at_ingest.append(r)

        decode_reqs = [r for r in self.slots
                       if r and r.state == ReqState.RUNNING]
        budget = self.ecfg.max_batched_tokens - len(decode_reqs)

        # --- pick prefill chunk (head PREFILL request) --------------------
        chunk_req, chunk_len = None, 0
        if not self.ecfg.decode_only:
            for r in self.slots:
                if r and r.state == ReqState.PREFILL:
                    chunk_req = r
                    break
            if chunk_req is not None:
                # prefill-only instances have no decodes, so their budget is
                # the full token batch — they too proceed chunk by chunk
                chunk_len = min(chunk_req.prefill_remaining, max(budget, 0))
                if chunk_len == 0:
                    chunk_req = None

        if chunk_req is None and not decode_reqs:
            # idle iteration (only transfers) — charge transfer time if any
            return transfer_time

        # --- execute ------------------------------------------------------
        prefill_ctx = chunk_req.context_len if chunk_req else 0
        if chunk_req is not None:
            tokens = chunk_req.prompt[
                chunk_req.context_len: chunk_req.context_len + chunk_len]
            completes = (chunk_req.context_len + chunk_len
                         >= chunk_req.input_len)
            first = self.executor.prefill_chunk(
                chunk_req.slot, tokens, chunk_req.context_len, completes,
                enc_emb=chunk_req.enc_emb if chunk_req.context_len == 0 else None)
            chunk_req.context_len += chunk_len

        if decode_reqs:
            slot_tokens, slot_lens = {}, {}
            for r in decode_reqs:
                # feed the last generated token; its cache position is
                # input_len + (#generated - 1)
                slot_tokens[r.slot] = r.generated[-1]
                slot_lens[r.slot] = r.total_ctx - 1
            new_tokens = self.executor.decode(slot_tokens, slot_lens)

        # --- timing -------------------------------------------------------
        decode_ctx_sum = float(sum(r.total_ctx for r in decode_reqs))
        duration = self.device.chunked_iter_time(
            chunk_len, prefill_ctx, decode_ctx_sum, len(decode_reqs))
        duration = max(duration, transfer_time)
        self.clock += duration
        for r in ttft_at_ingest:
            r.metrics.first_token_time = self.clock
            if r.done:
                r.metrics.finish_time = self.clock
                self._finish(r)

        # --- bookkeeping ----------------------------------------------------
        if chunk_req is not None and chunk_req.context_len >= chunk_req.input_len:
            if self.ecfg.prefill_only:
                chunk_req.first_token = first
                chunk_req.metrics.first_token_time = self.clock
                self._complete_prefill_instance(chunk_req)
            else:
                chunk_req.first_token = first
                chunk_req.generated.append(first)   # first output token
                chunk_req.metrics.first_token_time = self.clock
                if chunk_req.done:
                    chunk_req.metrics.finish_time = self.clock
                    self._finish(chunk_req)
                else:
                    chunk_req.state = ReqState.RUNNING

        if decode_reqs:
            for r in decode_reqs:
                tok = new_tokens[r.slot]
                r.generated.append(tok)
                if r.done:
                    r.metrics.token_times.append(self.clock)
                    r.metrics.finish_time = self.clock
                    self._finish(r)
                else:
                    r.metrics.token_times.append(self.clock)
        return duration

    # ------------------------------------------------------------------
    def _finish(self, req: Request):
        req.state = ReqState.FINISHED
        self.allocator.free(req.req_id)
        self.executor.reset_slot(req.slot)
        self.slots[req.slot] = None
        req.slot = None
        self.finished.append(req)

    def _complete_prefill_instance(self, req: Request):
        """Prefill-only instance: extract KV and release the slot; the
        orchestrator routes the payload to the decode instance."""
        req.kv_payload = self.executor.extract_kv(req.slot, req.context_len)
        self.allocator.free(req.req_id)
        self.slots[req.slot] = None
        req.slot = None
        req.state = ReqState.WAITING
        self.completed_prefills.append((self.clock, req))
