"""Continuous-batching inference engine with chunked prefill (vLLM-class).

One ``Engine`` models one serving instance (one device or pod slice). Each
``step()`` executes a single iteration. Batch composition is no longer the
engine's business: a pluggable :class:`~repro.scheduling.Scheduler` policy
(``EngineConfig.sched_policy``) turns the current slots/queue/allocator
state into an :class:`~repro.scheduling.IterationPlan` — which queued
requests to admit, which residents to preempt (recompute), which requests
decode, and which prefill chunks (possibly several requests packed into the
token budget) run. The engine applies the plan: it moves requests, grows
paged-KV allocations lazily via ``BlockAllocator.extend_to`` when the
policy schedules lazily, executes compute through the pluggable executor
(real JAX or null), and charges roofline time for the composed batch.

The engine doubles as:
  * the CPI (chunked prefill instance) of Cronus — requests arrive with
    ``partial_len`` set and a KV payload to ingest,
  * a standalone DP worker (chunked prefill + decode),
  * a decode-only / prefill-only instance for the disaggregated baselines
    (via ``prefill_only`` / ``decode_only``).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.core.balancer import CPIStats
from repro.core.request import ReqState, Request
from repro.kvcache import BlockAllocator
from repro.scheduling import IterationPlan, SchedulerView, make_scheduler


@dataclasses.dataclass
class EngineConfig:
    max_batched_tokens: int = 512      # chunked-prefill token budget B
    max_slots: int = 64                # resident request limit
    block_size: int = 16               # KV block granularity N_size
    num_kv_blocks: int = 4096          # KV pool size (from device HBM budget)
    prefill_only: bool = False         # disaggregated prefill instance
    decode_only: bool = False          # disaggregated decode instance
    sched_policy: str = "fcfs"         # see repro.scheduling.SCHEDULERS
    skip_ahead: Optional[bool] = None  # None -> policy default (fcfs: off)
    lazy_kv: Optional[bool] = None     # None -> policy default (fcfs: off)
    prefix_cache: bool = False         # shared-prefix KV reuse (off = seed)
    executor: str = "null"             # compute backend: null | real | paged
    host_kv_blocks: int = 0            # host-memory cache tier (0 = off)


class Engine:
    # trailing window (simulated seconds) over which busy_fraction() is
    # measured — the utilization signal the autoscaler's scale-down
    # hysteresis reads. Class attribute so tests can tighten it.
    BUSY_WINDOW = 20.0

    def __init__(self, name: str, cfg, engine_cfg: EngineConfig, device_model,
                 executor):
        self.name = name
        self.cfg = cfg
        self.ecfg = engine_cfg
        self.device = device_model
        self.executor = executor
        self.clock = 0.0
        self.allocator = BlockAllocator(engine_cfg.num_kv_blocks,
                                        engine_cfg.block_size,
                                        prefix_cache=engine_cfg.prefix_cache,
                                        host_blocks=engine_cfg.host_kv_blocks)
        self.scheduler = make_scheduler(engine_cfg.sched_policy, engine_cfg)
        self.slots: List[Optional[Request]] = [None] * engine_cfg.max_slots
        # Block-pool executors bind to the engine so attention can read
        # the live block tables (and the allocator's CoW hook can clone
        # pool rows). Slot/null executors have no such coupling.
        if hasattr(executor, "attach_engine"):
            executor.attach_engine(self)
        self.queue: Deque[Request] = deque()
        self.finished: List[Request] = []
        self.completed_prefills: List = []   # (time, req) from prefill-only role
        self.n_preemptions = 0               # recompute preemptions served
        # busy-time accounting for the autoscaler's utilization signal:
        # every executed iteration appends (end_clock, duration) here; the
        # log is pruned to BUSY_WINDOW seconds so busy_fraction() stays O(1)
        # amortised. busy_since marks when this engine joined the cluster
        # (reset by InferenceService.attach_endpoint), so a freshly
        # attached engine's fraction is over its own lifetime, not the
        # cluster's. Pure bookkeeping: never feeds metrics or scheduling.
        self.busy_since = 0.0
        self._work_log: Deque = deque()      # (end_clock, duration)
        # per-token emission hook for streaming consumers (InferenceService):
        # called as on_token(request, token_id, clock) at the moment each
        # output token's timestamp is recorded. None = no overhead.
        self.on_token = None
        # flight-recorder hook (repro.obs): InferenceService.start_trace
        # sets tracer + this engine's track handle. None = zero overhead —
        # every tracing site sits behind an `is not None` guard, so an
        # untraced run allocates nothing on this path.
        self.tracer = None
        self.trace_track = 0

    def _emit(self, req: Request, token: int):
        if self.on_token is not None:
            self.on_token(req, token, self.clock)

    def _trace_gauges(self, tracer):
        """Per-iteration gauge samples (tracing on only): queue depth,
        free KV blocks, trailing busy fraction."""
        resident = sum(1 for r in self.slots if r is not None)
        tracer.counter(self.trace_track, "queue_depth", self.clock,
                       {"queued": len(self.queue), "resident": resident})
        tracer.counter(self.trace_track, "free_kv_blocks", self.clock,
                       {"free": self.allocator.num_free})
        tracer.counter(self.trace_track, "busy_frac", self.clock,
                       {"busy": self.busy_fraction()})

    # ------------------------------------------------------------------
    # busy-time accounting (autoscaler utilization signal)
    # ------------------------------------------------------------------
    def _record_work(self, duration: float):
        if duration <= 0.0:
            return
        self._work_log.append((self.clock, duration))
        horizon = self.clock - self.BUSY_WINDOW
        while self._work_log and self._work_log[0][0] < horizon:
            self._work_log.popleft()

    def busy_fraction(self, window: Optional[float] = None) -> float:
        """Fraction of the trailing ``window`` simulated seconds this
        engine spent executing iterations (1.0 = saturated). The window
        is clipped to the engine's own lifetime (``busy_since``) so a
        freshly attached engine isn't reported idle for time it did not
        exist."""
        window = self.BUSY_WINDOW if window is None else window
        lo = max(self.clock - window, self.busy_since)
        span = self.clock - lo
        if span <= 0.0:
            return 0.0
        busy = sum(min(end, self.clock) - max(end - dur, lo)
                   for end, dur in self._work_log
                   if end > lo)
        return min(busy / span, 1.0)

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def add_request(self, req: Request, now: Optional[float] = None):
        if now is not None:
            self.clock = max(self.clock, now)
        req.state = ReqState.WAITING
        self.queue.append(req)

    def _view(self) -> SchedulerView:
        return SchedulerView(clock=self.clock, slots=self.slots,
                             queue=self.queue, allocator=self.allocator,
                             cfg=self.ecfg)

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slots):
            if r is None:
                return i
        return None

    def _place(self, req: Request):
        """Queue -> slot, per the plan (blocks reserved per the policy:
        full final context for conservative policies, prompt-only for lazy
        ones, which then grow via ``extend_to``). With prefix caching the
        block table is seeded from the cache first: every reused token
        advances ``context_len`` past its prefill. The last prompt token
        is never taken from the cache — its chunk computes the first
        output token."""
        slot = self._free_slot()
        assert slot is not None, "plan admitted with no free slot"
        if req.metrics.service_start_time is None:
            # first slot admission anywhere (PPI prefill views share the
            # metrics object; preemption-recompute re-placements keep the
            # original): the queueing/service boundary of TTFT
            req.metrics.service_start_time = self.clock
            if self.tracer is not None:
                self.tracer.instant(self.trace_track, "service_start",
                                    self.clock, {"req": req.req_id})
        if self.allocator.prefix_cache and req.input_len > 1:
            if req.context_len == 0 and req.kv_payload is None:
                shared = self.allocator.share_blocks(
                    req.req_id, req.prompt, max_tokens=req.input_len - 1)
                if shared:
                    req.context_len = shared
                    req.metrics.cached_prefix_tokens += shared
                    if self.tracer is not None:
                        self.tracer.instant(
                            self.trace_track, "prefix_hit", self.clock,
                            {"req": req.req_id, "tokens": shared})
            elif req.kv_payload is not None \
                    and req.context_len < req.input_len:
                # Cronus handoff mid-prompt: the cache may hold a longer
                # prefix than the PPI's partial — sharing it shortens the
                # chunked remainder too (fully-covered blocks dedupe even
                # when the match is shorter than the payload)
                shared = self.allocator.share_blocks(
                    req.req_id, req.prompt, max_tokens=req.input_len - 1)
                if shared > req.context_len:
                    req.metrics.cached_prefix_tokens += \
                        shared - req.context_len
                    if self.tracer is not None:
                        self.tracer.instant(
                            self.trace_track, "prefix_hit", self.clock,
                            {"req": req.req_id,
                             "tokens": shared - req.context_len})
                    req.context_len = shared
        # migrated decoders can carry more context than the policy's
        # admission reservation (context covers generated tokens too) —
        # the table must span the payload about to be injected
        need = max(self.scheduler.admission_tokens(req), req.context_len)
        if self.allocator.owned_blocks(req.req_id):
            self.allocator.extend_to(req.req_id, need)
        else:
            self.allocator.allocate(req.req_id, need)
        req.slot = slot
        self.slots[slot] = req
        self.executor.reset_slot(slot)
        if req.kv_payload is not None:
            req.state = ReqState.TRANSFER        # ingest during next iter
        elif req.context_len >= req.input_len:
            req.state = ReqState.RUNNING          # pre-prefilled elsewhere
        else:
            req.state = ReqState.PREFILL

    def _preempt(self, req: Request):
        """Preemption-by-recompute (vLLM-style): release the slot and all
        KV blocks, fold the generated tokens into the prompt (so the
        re-prefill reproduces the full context and the next completion
        token continues the sequence), and requeue at the front."""
        self.n_preemptions += 1
        if self.tracer is not None:
            self.tracer.instant(self.trace_track, "preempt", self.clock,
                                {"req": req.req_id,
                                 "folded_tokens": len(req.generated)})
        req.preempted = True
        if req.generated:
            req.prompt = np.concatenate(
                [req.prompt, np.asarray(req.generated, np.int32)])
            req.output_len -= len(req.generated)
            req.generated = []
        req.context_len = 0
        req.kv_payload = None
        self.allocator.free(req.req_id)
        self.executor.reset_slot(req.slot)
        self.slots[req.slot] = None
        req.slot = None
        req.state = ReqState.WAITING
        req.ready_time = self.clock
        self.queue.appendleft(req)

    def _apply(self, plan: IterationPlan):
        for r in plan.preempt:
            self._preempt(r)
        if plan.admit:
            admit_ids = {id(r) for r in plan.admit}
            self.queue = deque(r for r in self.queue
                               if id(r) not in admit_ids)
            for req in plan.admit:
                self._place(req)

    # ------------------------------------------------------------------
    # stats for the Balancer (paper step (1))
    # ------------------------------------------------------------------
    def stats(self) -> CPIStats:
        # Imminent decode load the Balancer must see, or it under-splits
        # right after a handoff: besides RUNNING residents this counts
        # TRANSFER residents whose context already covers the prompt —
        # they ingest and decode this very iteration.
        decoding = [r for r in self.slots if r and (
            r.state == ReqState.RUNNING
            or (r.state == ReqState.TRANSFER
                and r.context_len >= r.input_len))]
        imminent = []
        if self.scheduler.lazy_kv:
            # Honest-accounting mode (lazy policies only): delivered
            # handoffs still queued — ready, fully prefilled — decode as
            # soon as a slot frees, so count them up to the free-slot
            # capacity. Conservative policies keep the seed's exact
            # signal: the fcfs bit-identity contract covers the Balancer's
            # inputs, and its split decisions are calibrated to them.
            cap = sum(1 for s in self.slots if s is None)
            if cap:
                imminent = [r for r in self.queue
                            if r.ready_time <= self.clock
                            and r.context_len >= r.input_len][:cap]
        return CPIStats(
            n_decode=len(decoding) + len(imminent),
            decode_ctx_sum=float(sum(r.total_ctx for r in decoding)
                                 + sum(r.total_ctx for r in imminent)),
            free_kv_blocks=self.allocator.num_free,
            block_size=self.ecfg.block_size,
            max_batched_tokens=self.ecfg.max_batched_tokens,
        )

    # ------------------------------------------------------------------
    def has_work(self) -> bool:
        if self.queue and self._free_slot() is not None:
            return True
        return any(r is not None for r in self.slots)

    def runnable(self) -> bool:
        """True if step() would make progress right now."""
        if any(r is not None for r in self.slots):
            return True
        if self.queue and self._free_slot() is not None:
            return self.scheduler.has_admissible(self._view())
        return False

    def next_ready_time(self) -> Optional[float]:
        """If idle but queued work is in transit, when it becomes ready."""
        if any(r is not None for r in self.slots) or not self.queue:
            return None
        return self.scheduler.next_ready_time(self._view())

    # ------------------------------------------------------------------
    # one iteration
    # ------------------------------------------------------------------
    def step(self) -> float:
        """Execute one iteration; returns its simulated duration (s)."""
        tracer = self.tracer
        t_start = self.clock
        plan = self.scheduler.plan(self._view())
        if tracer is not None:
            n_admit, n_preempt = len(plan.admit), len(plan.preempt)
        self._apply(plan)

        # --- ingest pending KV transfers (overlapped with compute) -------
        transfer_time = 0.0
        ttft_at_ingest: List[Request] = []
        for r in self.slots:
            if r and r.state == ReqState.TRANSFER:
                self.executor.inject_kv(r.slot, r.kv_payload, r.context_len)
                if not r.local_payload:   # decode-offload: KV never moved
                    # the payload holds the PPI's partial_len tokens; a
                    # prefix-cache hit may have advanced context_len past
                    # it, but only the payload actually crosses the wire
                    moved = r.partial_len if r.partial_len else r.context_len
                    wire = self.device.transfer_time(moved)
                    transfer_time = max(transfer_time, wire)
                    if tracer is not None:
                        tracer.instant(self.trace_track, "kv_ingest",
                                       t_start, {"req": r.req_id,
                                                 "tokens": moved,
                                                 "wire_s": wire})
                r.kv_payload = None
                r.state = (ReqState.RUNNING if r.context_len >= r.input_len
                           else ReqState.PREFILL)
                if r.state is ReqState.RUNNING and r.first_token is not None:
                    # fully-prefilled elsewhere (disagg / Cronus fallback):
                    # TTFT counts the KV transfer (paper §5.1 fairness rule)
                    r.generated.append(r.first_token)
                    ttft_at_ingest.append(r)

        # a handoff whose ingest completed its whole output (output_len
        # fully produced elsewhere, e.g. 1-token outputs) must not decode
        # again — it finishes in the ttft_at_ingest handling below
        decode_reqs = [r for r in plan.decode if not r.done]
        if self.scheduler.lazy_kv:
            # dynamic paged-KV growth: each decoder's allocation must cover
            # its next token (the planner preempted victims so this fits)
            for r in decode_reqs:
                self.allocator.extend_to(r.req_id, r.total_ctx)

        # host-tier PCIe traffic this iteration generated (placements
        # promoting demoted chains, allocations demoting cold ones) is
        # DMA overlapped with compute, like the link transfers above
        if self.allocator.host_blocks:
            moved = self.allocator.take_pending_host_transfer_tokens()
            if moved:
                transfer_time = max(transfer_time,
                                    self.device.host_kv_time(moved))

        # Executed chunk lengths clamp to prefill_remaining as it stands
        # AFTER placement: a prefix-cache hit at _place advanced
        # context_len past the plan's view, so only the uncached tail runs
        # (and only it is charged below). Without caching the clamp is a
        # no-op and the executed chunks equal the plan's.
        chunks = [(c.req, n) for c in plan.prefill
                  if (n := min(c.chunk_len, c.req.prefill_remaining)) > 0]

        # chunk provenance for the trace, captured BEFORE execution moves
        # context_len: a chunk is *migrated prefill* — the remainder of a
        # prefill whose head ran elsewhere and crossed the wire — iff the
        # request carries a nonzero partial split, its KV actually moved
        # (not a local decode-offload), it is not a preemption recompute
        # (those restart from context 0 on local KV), and the chunk starts
        # at or past the split point. PPI-side views carry the same
        # partial_len but chunk below it, so they never count.
        if tracer is not None:
            chunk_info = [
                [r.req_id, n, r.context_len,
                 1 if (r.partial_len > 0 and not r.local_payload
                       and not r.preempted
                       and r.context_len >= r.partial_len) else 0]
                for r, n in chunks]
            migrated_tokens = sum(c[1] for c in chunk_info if c[3])

        if not chunks and not decode_reqs:
            # idle iteration (only transfers); ingest-completed requests
            # still pay the transfer before finishing (TTFT fairness rule)
            if ttft_at_ingest:
                self.clock += transfer_time
                for r in ttft_at_ingest:
                    r.metrics.first_token_time = self.clock
                    if tracer is not None:
                        tracer.instant(self.trace_track, "first_token",
                                       self.clock, {"req": r.req_id})
                    self._emit(r, r.generated[-1])
                    r.metrics.finish_time = self.clock
                    self._finish(r)
            self._record_work(transfer_time)
            if tracer is not None and transfer_time > 0.0:
                tracer.complete(
                    self.trace_track, "iter", t_start, self.clock,
                    {"n_decode": 0, "prefill_tokens": 0,
                     "migrated_prefill_tokens": 0, "n_admit": n_admit,
                     "n_preempt": n_preempt, "transfer_s": transfer_time,
                     "chunks": []})
                self._trace_gauges(tracer)
            return transfer_time

        # --- execute prefill chunks (possibly several requests) -----------
        prefill_tokens = sum(n for _, n in chunks)
        if len(chunks) == 1:
            prefill_ctx: float = chunks[0][0].context_len
        elif chunks:
            # token-weighted mean context start for the roofline attn term
            prefill_ctx = sum(n * r.context_len
                              for r, n in chunks) / prefill_tokens
        else:
            prefill_ctx = 0
        first_tokens: Dict[str, Optional[int]] = {}
        for r, n in chunks:
            tokens = r.prompt[r.context_len: r.context_len + n]
            completes = r.context_len + n >= r.input_len
            first = self.executor.prefill_chunk(
                r.slot, tokens, r.context_len, completes,
                enc_emb=r.enc_emb if r.context_len == 0 else None)
            r.context_len += n
            if completes:
                first_tokens[r.req_id] = first

        if decode_reqs:
            slot_tokens, slot_lens = {}, {}
            for r in decode_reqs:
                # feed the last generated token; its cache position is
                # input_len + (#generated - 1)
                slot_tokens[r.slot] = r.generated[-1]
                slot_lens[r.slot] = r.total_ctx - 1
            new_tokens = self.executor.decode(slot_tokens, slot_lens)

        # --- timing -------------------------------------------------------
        decode_ctx_sum = float(sum(r.total_ctx for r in decode_reqs))
        duration = self.device.chunked_iter_time(
            prefill_tokens, prefill_ctx, decode_ctx_sum, len(decode_reqs))
        duration = max(duration, transfer_time)
        self.clock += duration
        self._record_work(duration)
        if tracer is not None:
            tracer.complete(
                self.trace_track, "iter", t_start, self.clock,
                {"n_decode": len(decode_reqs),
                 "decode_ctx": decode_ctx_sum,
                 "prefill_tokens": prefill_tokens,
                 "migrated_prefill_tokens": migrated_tokens,
                 "n_admit": n_admit, "n_preempt": n_preempt,
                 "transfer_s": transfer_time, "chunks": chunk_info})
            self._trace_gauges(tracer)
        for r in ttft_at_ingest:
            r.metrics.first_token_time = self.clock
            if tracer is not None:
                tracer.instant(self.trace_track, "first_token",
                               self.clock, {"req": r.req_id})
            self._emit(r, r.generated[-1])
            if r.done:
                r.metrics.finish_time = self.clock
                self._finish(r)

        # --- bookkeeping ----------------------------------------------------
        for r, _ in chunks:
            if r.context_len < r.input_len:
                continue
            first = first_tokens[r.req_id]
            # output_len == 0 <=> a PPI prefill view; an offloaded decoder
            # recomputing after preemption carries output_len > 0 and must
            # take the normal token-emitting path even on a prefill-only
            # instance
            if self.ecfg.prefill_only and r.output_len == 0:
                r.first_token = first
                r.metrics.first_token_time = self.clock
                if tracer is not None:
                    # PPI prefill view: views share the original's metrics
                    # object, so this timestamp is later superseded by the
                    # CPI's — the report keeps the last one, matching the
                    # overwrite semantics below
                    tracer.instant(self.trace_track, "first_token",
                                   self.clock, {"req": r.req_id})
                self._complete_prefill_instance(r)
            else:
                r.first_token = first
                r.generated.append(first)   # first output token
                self._emit(r, first)
                if r.preempted and r.input_len > r.metrics.input_len:
                    # recompute after a preemption that folded delivered
                    # tokens into the prompt (input_len grew past the
                    # original): TTFT already happened for real, this
                    # completion token is an inter-token interval
                    r.metrics.token_times.append(self.clock)
                else:
                    # TTFT is this completion — overwriting a PPI-side
                    # timestamp for Cronus partial prefills (views share
                    # the metrics object), as the seed did; a request
                    # preempted mid-prefill before emitting any token
                    # lands here too, so a stale PPI timestamp can never
                    # masquerade as a delivered TTFT
                    r.metrics.first_token_time = self.clock
                    if tracer is not None:
                        tracer.instant(self.trace_track, "first_token",
                                       self.clock, {"req": r.req_id})
                if r.done:
                    r.metrics.finish_time = self.clock
                    self._finish(r)
                else:
                    r.state = ReqState.RUNNING

        if decode_reqs:
            for r in decode_reqs:
                tok = new_tokens[r.slot]
                r.generated.append(tok)
                self._emit(r, tok)
                if r.done:
                    r.metrics.token_times.append(self.clock)
                    r.metrics.finish_time = self.clock
                    self._finish(r)
                else:
                    r.metrics.token_times.append(self.clock)
        return duration

    # ------------------------------------------------------------------
    def _finish(self, req: Request):
        if self.tracer is not None:
            self.tracer.instant(self.trace_track, "finish", self.clock,
                                {"req": req.req_id,
                                 "n_generated": len(req.generated)})
            self.tracer.async_end(self.tracer.control, "request",
                                  self.clock, req.req_id)
        req.state = ReqState.FINISHED
        if self.allocator.prefix_cache:
            # register the finished sequence (prompt + generated) in the
            # prefix index: its blocks are retained as evictable cache
            seq = (np.concatenate([req.prompt,
                                   np.asarray(req.generated, np.int32)])
                   if req.generated else req.prompt)
            self.allocator.free(req.req_id, cache_tokens=seq)
        else:
            self.allocator.free(req.req_id)
        self.executor.reset_slot(req.slot)
        self.slots[req.slot] = None
        req.slot = None
        self.finished.append(req)

    def remove_request(self, req_id: str) -> Optional[Request]:
        """Pull a queued or resident request out of this engine: release
        its slot and KV blocks without touching its metrics or terminal
        state (the caller decides whether this is a cancellation or a
        migration). Returns the request, or None if this engine does not
        hold it. Call between iterations only (plans hold no state across
        ``step()`` calls)."""
        for i, r in enumerate(self.queue):
            if r.req_id == req_id:
                del self.queue[i]
                self.allocator.free(req_id)    # no-op when nothing is owned
                return r
        for r in self.slots:
            if r is not None and r.req_id == req_id:
                self.allocator.free(req_id)
                self.executor.reset_slot(r.slot)
                self.slots[r.slot] = None
                r.slot = None
                return r
        return None

    def cancel(self, req_id: str) -> Optional[Request]:
        """Abort a queued or resident request mid-flight: release its slot
        and KV blocks (nothing is registered in the prefix cache — the
        sequence never completed) and record the ``cancelled`` terminal
        state in its metrics. Returns the request, or None if this engine
        does not hold it."""
        r = self.remove_request(req_id)
        return self._cancel(r) if r is not None else None

    def drain_requests(self) -> List[Request]:
        """Evict everything this engine holds for recompute elsewhere
        (endpoint detach): residents leave via the preemption-by-recompute
        path (generated tokens folded into the prompt, KV freed), then the
        whole queue — including requests the preemptions just requeued —
        is popped and stripped of engine-local state (payloads, partial
        prefills, first tokens) because the KV they reference lives on the
        hardware being removed. Returns the displaced requests; afterwards
        the engine holds no work and its allocator invariants are clean."""
        for r in list(self.slots):
            if r is not None:
                self._preempt(r)
        displaced = []
        while self.queue:
            r = self.queue.popleft()
            r.kv_payload = None
            r.local_payload = False
            r.first_token = None
            r.partial_len = 0
            r.context_len = 0
            r.state = ReqState.WAITING
            r.ready_time = r.arrival
            self.allocator.free(r.req_id)      # no-op when nothing is owned
            displaced.append(r)
        return displaced

    def migrate_requests(self) -> List[Request]:
        """Evict everything this engine holds, *keeping KV where it can
        move* (endpoint detach with migration): residents leave with their
        cache contents extracted into a portable ``kv_payload`` (decoders
        carry ``total_ctx - 1`` tokens, mid-prefill requests their partial
        context) instead of recomputing; queued requests that already
        carry a payload keep it. The runtime routes the displaced requests
        to endpoints that can ingest the KV — and strips the payload back
        to the recompute path when none can. Afterwards the engine holds
        no work and its allocator invariants are clean."""
        displaced: List[Request] = []
        for r in list(self.slots):
            if r is not None:
                displaced.append(self._extract_resident(r))
        while self.queue:
            r = self.queue.popleft()
            self.allocator.free(r.req_id)   # no-op when nothing is owned
            if r.kv_payload is None:
                # plain queued arrival: nothing engine-local to preserve
                r.first_token = None
                r.partial_len = 0
                r.context_len = 0
                r.ready_time = r.arrival
            # else: a delivered handoff's payload is portable data — keep
            # its context/partial/first-token exactly as the PPI left them
            r.local_payload = False
            r.state = ReqState.WAITING
            displaced.append(r)
        return displaced

    def _extract_resident(self, r: Request) -> Request:
        """Pull one resident out with its KV as a portable payload (or
        stripped for recompute when the cache holds nothing yet)."""
        if r.state is ReqState.TRANSFER:
            # the un-ingested payload is already portable: keep it
            r.ready_time = max(r.ready_time, self.clock)
        else:
            # decoders: KV covers total_ctx - 1 (the newest token's KV is
            # written by its own decode step); prefills: context_len
            k = r.total_ctx - 1 if r.generated else r.context_len
            if k > 0:
                r.kv_payload = self.executor.extract_kv(r.slot, k)
                r.context_len = k
                r.partial_len = 0       # the whole payload crosses the wire
                if r.generated:
                    r.first_token = None    # already emitted — never re-emit
                r.ready_time = max(r.ready_time, self.clock)
            else:
                r.kv_payload = None
                r.first_token = None
                r.partial_len = 0
                r.context_len = 0
                r.ready_time = r.arrival
        r.local_payload = False
        self.allocator.free(r.req_id)
        self.executor.reset_slot(r.slot)
        self.slots[r.slot] = None
        r.slot = None
        r.state = ReqState.WAITING
        return r

    def _cancel(self, req: Request) -> Request:
        self.allocator.free(req.req_id)    # no-op when nothing is owned
        req.kv_payload = None
        req.state = ReqState.CANCELLED
        req.metrics.cancelled = True
        req.metrics.cancel_time = self.clock
        if self.tracer is not None:
            self.tracer.instant(self.trace_track, "cancel", self.clock,
                                {"req": req.req_id})
            self.tracer.async_end(self.tracer.control, "request",
                                  self.clock, req.req_id,
                                  {"cancelled": True})
        return req

    def _complete_prefill_instance(self, req: Request):
        """Prefill-only instance: extract KV and release the slot; the
        orchestrator routes the payload to the decode instance. With
        prefix caching the prefilled prompt is registered, so repeated
        shared prefixes shorten the PPI's split-prefill portion too."""
        req.kv_payload = self.executor.extract_kv(req.slot, req.context_len)
        if self.allocator.prefix_cache:
            self.allocator.free(req.req_id,
                                cache_tokens=req.prompt[:req.context_len])
        else:
            self.allocator.free(req.req_id)
        self.slots[req.slot] = None
        req.slot = None
        req.state = ReqState.WAITING
        self.completed_prefills.append((self.clock, req))
