"""The paper's primary contribution: partially disaggregated prefill —
balancer (Alg. 1), execution-time predictors (Eq. 1-3), the continuous-
batching engines, the Cronus orchestrator, and the four baselines."""
from repro.core.balancer import Balancer, CPIStats
from repro.core.cronus import (CronusSystem, FixedBalancer, build_cronus,
                               build_disaggregated)
from repro.core.engine import Engine, EngineConfig
from repro.core.request import ReqState, Request

__all__ = [
    "Balancer", "CPIStats", "CronusSystem", "FixedBalancer",
    "build_cronus", "build_disaggregated", "Engine", "EngineConfig",
    "ReqState", "Request",
]
