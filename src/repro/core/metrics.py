"""Serving QoE metrics: throughput, TTFT P99, TBT P99 (paper §2, §5),
plus SLO attainment (goodput) for scheduler ablations."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class RequestMetrics:
    req_id: str
    arrival: float
    input_len: int
    output_len: int
    first_token_time: Optional[float] = None    # absolute time of first token
    token_times: List[float] = dataclasses.field(default_factory=list)
    finish_time: Optional[float] = None
    # absolute time the request first won a KV slot on ANY engine (the PPI
    # prefill view shares this object, so for Cronus it is PPI admission).
    # Recorded unconditionally (inert for the seed aggregates); surfaced
    # only through the opt-in queueing keys the open-loop driver requests.
    service_start_time: Optional[float] = None
    cached_prefix_tokens: int = 0     # prompt tokens served from prefix cache
    # terminal state: a request either finishes (finish_time set) or is
    # cancelled mid-flight (cancelled set, finish_time stays None) —
    # cancelled requests never enter throughput/latency aggregates
    cancelled: bool = False
    cancel_time: Optional[float] = None

    @property
    def ttft(self) -> float:
        return self.first_token_time - self.arrival

    @property
    def queueing_delay(self) -> Optional[float]:
        """Arrival -> first slot admission on any engine: the part of TTFT
        spent waiting rather than being served. None until admitted."""
        if self.service_start_time is None:
            return None
        return self.service_start_time - self.arrival

    @property
    def tbts(self) -> List[float]:
        ts = [self.first_token_time] + self.token_times
        return [b - a for a, b in zip(ts[:-1], ts[1:])]


def percentile(values, p: float) -> float:
    if not values:
        return float("nan")
    return float(np.percentile(np.asarray(values), p))


def meets_slo(r: RequestMetrics, ttft_slo: float, tbt_slo: float,
              tbt_pct: float = 99.0) -> bool:
    """Did one completed request hit both latency deadlines? TTFT against
    ``ttft_slo``; the per-request P``tbt_pct`` inter-token gap against
    ``tbt_slo`` (a single straggler token shouldn't fail a request whose
    stream was otherwise smooth)."""
    if r.finish_time is None or r.first_token_time is None:
        return False
    if r.ttft > ttft_slo:
        return False
    tbts = r.tbts
    return not tbts or percentile(tbts, tbt_pct) <= tbt_slo


def slo_attainment(reqs: List[RequestMetrics], ttft_slo: float,
                   tbt_slo: float, tbt_pct: float = 99.0) -> float:
    """Goodput: fraction of ALL submitted requests that completed within
    both deadlines (incomplete requests count as misses)."""
    if not reqs:
        return float("nan")
    ok = sum(1 for r in reqs if meets_slo(r, ttft_slo, tbt_slo, tbt_pct))
    return ok / len(reqs)


def aggregate(reqs: List[RequestMetrics],
              ttft_slo: Optional[float] = None,
              tbt_slo: Optional[float] = None,
              queueing: bool = False,
              utilization: Optional[Dict[str, Dict[str, float]]] = None
              ) -> Dict[str, float]:
    """Fleet QoE summary. Passing both SLOs adds a ``goodput`` key;
    ``queueing=True`` (requested only by the open-loop driver) adds the
    queueing/service split of TTFT. ``utilization`` attaches a prebuilt
    per-endpoint breakdown (busy_frac, queued-age max, dispatched count —
    see ``InferenceService.metrics(utilization=True)``) under one
    ``"utilization"`` key. All opt-in: the default call returns exactly
    the seed's dict, so existing run metrics stay bit-identical."""
    done = [r for r in reqs if r.finish_time is not None and not r.cancelled]
    n_cancelled = sum(1 for r in reqs if r.cancelled)
    if not done:
        out = {"throughput": 0.0, "ttft_p99": float("nan"),
               "tbt_p99": float("nan"), "completed": 0}
        if n_cancelled:
            out["cancelled"] = n_cancelled
        if queueing:
            out.update(queueing_p50=float("nan"), queueing_p99=float("nan"),
                       ttft_service_p99=float("nan"))
        if ttft_slo is not None and tbt_slo is not None:
            out["goodput"] = 0.0 if reqs else float("nan")
        if utilization is not None:
            out["utilization"] = utilization
        return out
    t0 = min(r.arrival for r in done)
    t1 = max(r.finish_time for r in done)
    ttfts = [r.ttft for r in done if r.first_token_time is not None]
    tbts = [tbt for r in done for tbt in r.tbts]
    out = {
        "throughput": len(done) / max(t1 - t0, 1e-9),
        "ttft_p50": percentile(ttfts, 50),
        "ttft_p99": percentile(ttfts, 99),
        "tbt_p50": percentile(tbts, 50),
        "tbt_p99": percentile(tbts, 99),
        "completed": len(done),
        "makespan": t1 - t0,
    }
    if n_cancelled:
        # cancellation key appears only when cancels happened, so a
        # cancel-free run's dict stays byte-identical to the seed's
        out["cancelled"] = n_cancelled
    saved = sum(r.cached_prefix_tokens for r in done)
    if saved:
        # Prefix-cache keys appear only when the cache actually hit, so a
        # cache-off run's dict is byte-identical to the seed's. The rate
        # is tokens saved over prompt tokens ingested — a savings ratio,
        # not a probability: cached_prefix_tokens accumulates across the
        # PPI and CPI sides of one Cronus request and across
        # preemption-recompute cycles (whose folded prompts re-share), so
        # it can rarely exceed 1.
        out["prefill_tokens_saved"] = saved
        out["prefix_cache_hit_rate"] = saved / max(
            sum(r.input_len for r in done), 1)
    if queueing:
        # TTFT = queueing (arrival -> first slot) + service (slot -> first
        # token). Opt-in: only the open-loop driver asks, so closed-loop
        # replay dicts stay byte-identical to the seed's.
        qs = [q for r in done if (q := r.queueing_delay) is not None]
        out["queueing_p50"] = percentile(qs, 50)
        out["queueing_p99"] = percentile(qs, 99)
        svc = [r.ttft - r.queueing_delay for r in done
               if r.first_token_time is not None
               and r.queueing_delay is not None]
        out["ttft_service_p99"] = percentile(svc, 99)
    if ttft_slo is not None and tbt_slo is not None:
        out["goodput"] = slo_attainment(reqs, ttft_slo, tbt_slo)
    if utilization is not None:
        out["utilization"] = utilization
    return out
