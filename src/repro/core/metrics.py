"""Serving QoE metrics: throughput, TTFT P99, TBT P99 (paper §2, §5)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class RequestMetrics:
    req_id: str
    arrival: float
    input_len: int
    output_len: int
    first_token_time: Optional[float] = None    # absolute time of first token
    token_times: List[float] = dataclasses.field(default_factory=list)
    finish_time: Optional[float] = None

    @property
    def ttft(self) -> float:
        return self.first_token_time - self.arrival

    @property
    def tbts(self) -> List[float]:
        ts = [self.first_token_time] + self.token_times
        return [b - a for a, b in zip(ts[:-1], ts[1:])]


def percentile(values, p: float) -> float:
    if not values:
        return float("nan")
    return float(np.percentile(np.asarray(values), p))


def aggregate(reqs: List[RequestMetrics]) -> Dict[str, float]:
    done = [r for r in reqs if r.finish_time is not None]
    if not done:
        return {"throughput": 0.0, "ttft_p99": float("nan"),
                "tbt_p99": float("nan"), "completed": 0}
    t0 = min(r.arrival for r in done)
    t1 = max(r.finish_time for r in done)
    ttfts = [r.ttft for r in done if r.first_token_time is not None]
    tbts = [tbt for r in done for tbt in r.tbts]
    return {
        "throughput": len(done) / max(t1 - t0, 1e-9),
        "ttft_p50": percentile(ttfts, 50),
        "ttft_p99": percentile(ttfts, 99),
        "tbt_p50": percentile(tbts, 50),
        "tbt_p99": percentile(tbts, 99),
        "completed": len(done),
        "makespan": t1 - t0,
    }
