"""Request lifecycle for the serving engines."""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, List, Optional

import numpy as np

from repro.core.metrics import RequestMetrics


class ReqState(enum.Enum):
    WAITING = "waiting"        # in a queue, no cache slot
    TRANSFER = "transfer"      # admitted; KV payload arriving (Cronus/disagg)
    PREFILL = "prefill"        # chunked prefill in progress
    RUNNING = "running"        # decoding
    FINISHED = "finished"
    CANCELLED = "cancelled"    # aborted mid-flight (slots/KV blocks freed)


@dataclasses.dataclass
class Request:
    req_id: str
    prompt: np.ndarray                    # int32 [L_in]
    output_len: int
    arrival: float = 0.0
    enc_emb: Optional[np.ndarray] = None  # whisper-style encoder inputs (stub)
    session: Optional[str] = None         # conversation id (router affinity)

    # Cronus bookkeeping
    partial_len: int = 0                  # tokens prefilled by the PPI
    kv_payload: Any = None                # extracted cache slices in transit
    first_token: Optional[int] = None     # produced by PPI if partial == full
    local_payload: bool = False           # payload stays on-device (offload)
    kv_src: Optional[str] = None          # pool the payload was extracted from

    # engine-local state
    ready_time: float = 0.0               # earliest time this engine may run it
    state: ReqState = ReqState.WAITING
    slot: Optional[int] = None
    context_len: int = 0                  # tokens resident in this engine's cache
    generated: List[int] = dataclasses.field(default_factory=list)
    preempted: bool = False               # ever preempted (recompute pending/done)
    metrics: Optional[RequestMetrics] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32)
        if self.metrics is None:
            self.metrics = RequestMetrics(self.req_id, self.arrival,
                                          len(self.prompt), self.output_len)

    @property
    def input_len(self) -> int:
        return int(len(self.prompt))

    @property
    def prefill_remaining(self) -> int:
        return max(self.input_len - self.context_len, 0)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.output_len

    @property
    def total_ctx(self) -> int:
        """Context length during decode (prompt + generated so far)."""
        return self.input_len + len(self.generated)
