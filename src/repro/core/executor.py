"""Executors: the compute backends of the serving engines.

``RealExecutor`` runs actual JAX forwards on a slot-based cache (functional
correctness at reduced scale — the engine's tokens must match a monolithic
run bit-for-bit). ``PagedRealExecutor`` runs the same math over a block-pool
KV layout driven by the engine's live :class:`~repro.kvcache.BlockAllocator`
tables — attention reads exactly the blocks a request owns (paged-attention
kernels via :mod:`repro.kernels.ops`), so prefix-cache hits, copy-on-write
shares and Cronus PPI→CPI handoffs work on real compute. ``NullExecutor``
skips compute entirely (scheduling + timing studies at paper scale —
Tables 2-3, Fig. 4). All sit behind the same interface, so the
scheduler/balancer code under test is identical.

Slot-garbage invariant (why batched forwards are safe): forwards always run
over ALL slots; rows of slots not participating this iteration write
garbage K/V at indices beyond their valid region. Validity is defined
exclusively by host-managed ``kv_positions``, which only ever advance for
participating slots, and any later advance overwrites those indices with
real K/V first. Freed slots reset their position row to -1.

The paged pool has the same invariant per block row: padded/inactive lanes
write into a dedicated trash page that no block table references, and
attention masks by ``context_lens`` / kv positions, never by content.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp


def _pow2_bucket(n: int, lo: int = 16) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class BucketCache:
    """Single home for power-of-two shape bucketing + compilation accounting.

    ``jax.jit`` caches one executable per distinct argument-shape tuple, so
    every *new* bucketed shape an executor dispatches is exactly one XLA
    compilation. Executors funnel all shape choices through one instance;
    ``compile_stats()`` then lets tests assert a fixed compilation budget
    over a full trace instead of hoping recompilation stays bounded.
    """

    def __init__(self):
        self._shapes: Dict[str, Dict[Tuple[int, ...], int]] = {}

    def bucket(self, n: int, lo: int = 16) -> int:
        return _pow2_bucket(n, lo)

    def record(self, kind: str, *shape: int) -> bool:
        """Note one dispatch of ``kind`` at a bucketed ``shape``. Returns
        True when the shape is new (i.e. this dispatch compiles)."""
        seen = self._shapes.setdefault(kind, {})
        new = shape not in seen
        seen[shape] = seen.get(shape, 0) + 1
        return new

    def compile_stats(self) -> Dict[str, int]:
        """Per-kind distinct compiled shapes, plus totals."""
        out = {kind: len(seen) for kind, seen in self._shapes.items()}
        out["total_shapes"] = sum(len(s) for s in self._shapes.values())
        out["dispatches"] = sum(c for s in self._shapes.values()
                                for c in s.values())
        return out


# Margin for deterministic greedy tie-breaking. XLA CPU results carry small
# environment-dependent jitter (heap alignment changes SIMD reduction tails,
# ~1e-4 with fp32); plain argmax then flips near-ties and the token stream
# cascades. Reproducible serving instead picks the LOWEST token id among all
# logits within this margin of the max — stable under jitter << margin.
GREEDY_TIE_MARGIN = 0.05


def robust_greedy(logits_row) -> int:
    row = np.asarray(logits_row, np.float32)
    top = row.max()
    return int(np.nonzero(row >= top - GREEDY_TIE_MARGIN)[0][0])


class NullExecutor:
    """No compute; emits deterministic dummy tokens."""

    def __init__(self):
        self._counter = 0

    def prefill_chunk(self, slot, tokens, ctx_len, completes, enc_emb=None):
        if completes:
            self._counter += 1
            return self._counter
        return None

    def decode(self, slot_tokens: Dict[int, int], slot_lens: Dict[int, int]):
        out = {}
        for s in slot_tokens:
            self._counter += 1
            out[s] = self._counter
        return out

    def extract_kv(self, slot, upto):
        return {"_null": upto}

    def inject_kv(self, slot, payload, upto):
        pass

    def reset_slot(self, slot):
        pass


class RealExecutor:
    """JAX execution over a slot-based unified cache with host-managed
    positions. Chunk lengths are padded to power-of-two buckets to bound
    recompilation."""

    def __init__(self, model, params, *, max_slots: int, s_kv: int,
                 chunk_pad: Optional[int] = None, greedy: bool = True):
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.max_slots = max_slots
        self.s_kv = s_kv
        # Fixed chunk width: padding every prefill chunk to one width keeps
        # all forwards shape-identical, so XLA reductions are bit-identical
        # across schedules (token streams then match any same-width oracle).
        self.chunk_pad = chunk_pad
        self.cache = model.init_cache(max_slots, s_kv)
        self.pos = np.full((max_slots, s_kv), -1, np.int32)   # host positions
        self.lens = np.zeros((max_slots,), np.int32)          # host lengths
        self._fwd = jax.jit(
            lambda p, inp, cache, cl, pos, kvp, dec: model.forward(
                p, inp, cache, cl, positions=pos, kv_positions=kvp,
                decode=dec),
            static_argnames=("dec",))
        self._enc_dec = self.cfg.enc_dec
        self.buckets = BucketCache()

    def compile_stats(self) -> Dict[str, int]:
        return self.buckets.compile_stats()

    # ------------------------------------------------------------------
    def _run(self, inputs, positions, decode: bool, active_mask=None,
             enc_out=None):
        kvp = jnp.asarray(self.pos)
        cl = jnp.asarray(self.lens)
        if self._enc_dec:
            logits, new_cache, _ = self.model.forward(
                self.params, jnp.asarray(inputs), self.cache, cl,
                positions=jnp.asarray(positions), kv_positions=kvp,
                enc_out=enc_out, decode=decode)
        else:
            logits, new_cache, _ = self._fwd(
                self.params, jnp.asarray(inputs), self.cache, cl,
                jnp.asarray(positions), kvp, decode)
        # Attention-cache garbage written to inactive slots is masked by
        # positions, but recurrent SSM state is not — restore it for slots
        # that did not participate in this forward.
        if active_mask is not None and "h" in new_cache.get("stack", {}):
            m = jnp.asarray(active_mask)
            old, new = self.cache["stack"], dict(new_cache["stack"])
            for key in ("h", "conv"):
                sel = m.reshape((1, -1) + (1,) * (old[key].ndim - 2))
                new[key] = jnp.where(sel, new[key], old[key])
            new_cache = dict(new_cache)
            new_cache["stack"] = new
        self.cache = new_cache
        return logits

    def prefill_chunk(self, slot: int, tokens: np.ndarray, ctx_len: int,
                      completes: bool, enc_emb=None) -> Optional[int]:
        """Run one prefill chunk for `slot`. Returns first token if the
        prompt completes with this chunk."""
        c = len(tokens)
        if self.chunk_pad and c <= self.chunk_pad:
            cb = self.chunk_pad
        else:
            cb = self.buckets.bucket(c)
        self.buckets.record("prefill", self.max_slots, cb)
        inputs = np.zeros((self.max_slots, cb), np.int32)
        positions = np.full((self.max_slots, cb), -1, np.int32)
        inputs[slot, :c] = tokens
        positions[slot, :c] = ctx_len + np.arange(c)
        # mark new positions valid for this slot (host-side)
        idx = (ctx_len + np.arange(c)) % self.s_kv
        self.pos[slot, idx] = ctx_len + np.arange(c)
        if self._enc_dec and enc_emb is not None:
            # run the encoder for this request only and install its
            # cross-KV into the slot (never clobbering other slots)
            assert enc_emb.shape[0] == self.cache["cross_k"].shape[2], (
                "encoder input length must match the cross-KV cache "
                f"({enc_emb.shape[0]} vs {self.cache['cross_k'].shape[2]}); "
                "pad/crop the frontend-stub embeddings to enc_seq_len")
            enc_out = self.model.encode(self.params,
                                        jnp.asarray(enc_emb)[None])
            ck, cv = self.model.compute_cross_kv(self.params, enc_out)
            cache = dict(self.cache)
            cache["cross_k"] = cache["cross_k"].at[:, slot].set(ck[:, 0])
            cache["cross_v"] = cache["cross_v"].at[:, slot].set(cv[:, 0])
            self.cache = cache
        mask = np.zeros((self.max_slots,), bool)
        mask[slot] = True
        logits = self._run(inputs, positions, decode=False, active_mask=mask)
        self.lens[slot] = ctx_len + c
        if completes:
            return robust_greedy(logits[slot, c - 1])
        return None

    def decode(self, slot_tokens: Dict[int, int],
               slot_lens: Dict[int, int]) -> Dict[int, int]:
        """One decode step for the given slots. Returns slot -> next token."""
        self.buckets.record("decode", self.max_slots, 1)
        inputs = np.zeros((self.max_slots, 1), np.int32)
        positions = np.full((self.max_slots, 1), -1, np.int32)
        mask = np.zeros((self.max_slots,), bool)
        for s, tok in slot_tokens.items():
            inputs[s, 0] = tok
            positions[s, 0] = slot_lens[s]
            self.pos[s, slot_lens[s] % self.s_kv] = slot_lens[s]
            mask[s] = True
        logits = self._run(inputs, positions, decode=True, active_mask=mask)
        out = {}
        for s in slot_tokens:
            out[s] = robust_greedy(logits[s, 0])
            self.lens[s] = slot_lens[s] + 1
        return out

    # ------------------------------------------------------------------
    # KV handoff. Attention caches (k/v, MLA ckv/kpe) carry a sequence
    # axis at dim 2 of each [L, slot, S_kv, ...] leaf; only the first
    # ``upto`` positions are valid at extract time, so only they travel —
    # the PPI->CPI payload is sized by the partial prefill, not by the
    # padded slot width. Recurrent state (SSM h/conv — conv's pseudo-seq
    # axis is kernel taps, not positions) and cross-KV move whole.
    _SEQ_KEYS = frozenset(("k", "v", "ckv", "kpe"))

    def extract_kv(self, slot: int, upto: int):
        """Pull one slot's cache slices (the PPI->CPI payload)."""
        def take(key, a):
            return a[:, slot, :upto] if key in self._SEQ_KEYS else a[:, slot]

        payload = {"stack": {k: take(k, a)
                             for k, a in self.cache["stack"].items()}}
        if "dense" in self.cache:
            payload["dense"] = {k: take(k, a)
                                for k, a in self.cache["dense"].items()}
        for k in ("cross_k", "cross_v"):
            if k in self.cache:
                payload[k] = self.cache[k][:, slot]
        payload["_upto"] = upto
        return payload

    def inject_kv(self, slot: int, payload, upto: int):
        """Install a transferred payload into `slot` and mark [0, upto) valid."""
        def put(key, dst, src):
            if key in self._SEQ_KEYS:
                return dst.at[:, slot, :src.shape[1]].set(src)
            return dst.at[:, slot].set(src)

        cache = dict(self.cache)
        cache["stack"] = {k: put(k, a, payload["stack"][k])
                          for k, a in self.cache["stack"].items()}
        if "dense" in payload:
            cache["dense"] = {k: put(k, a, payload["dense"][k])
                              for k, a in self.cache["dense"].items()}
        for k in ("cross_k", "cross_v"):
            if k in payload:
                cache[k] = cache[k].at[:, slot].set(payload[k])
        self.cache = cache
        self.pos[slot, :] = -1
        self.pos[slot, :upto] = np.arange(upto)
        self.lens[slot] = upto

    def reset_slot(self, slot: int):
        self.pos[slot, :] = -1
        self.lens[slot] = 0
        # Attention-cache garbage is masked out by positions, but recurrent
        # state (SSM/hybrid) has no positional validity — zero it explicitly.
        stack = self.cache["stack"]
        if "h" in stack:
            cache = dict(self.cache)
            new_stack = dict(stack)
            for key in ("h", "conv"):
                new_stack[key] = stack[key].at[:, slot].set(0)
            cache["stack"] = new_stack
            self.cache = cache


class PagedRealExecutor:
    """JAX execution over a block-pool KV cache driven by the engine's live
    block tables.

    Layout: per layer, K and V pools of shape ``[num_blocks + 1, block_size,
    n_kv_heads, head_dim]`` (stacked to ``[L, P+1, bs, Kv, D]`` for the layer
    scan). Pool row ``i`` *is* allocator block ``i`` — the engine's
    :class:`~repro.kvcache.BlockAllocator` decides placement and this
    executor just reads/writes through the tables, so:

      * prefix-cache hits skip real prefill compute (retained blocks keep
        their K/V rows; ``share_blocks`` only bumps refcounts),
      * copy-on-write divergence clones one block row (the allocator's
        ``on_cow`` hook, registered at :meth:`attach_engine`),
      * Cronus PPI→CPI ``extract_kv``/``inject_kv`` move only the blocks
        covering the partial prefill — and skip positions the target's
        cache already shares (a block-id remap, not a slot-cache rewrite).

    The extra pool row (index ``num_blocks``) is a trash page: padded batch
    lanes and padded chunk tokens write their garbage K/V there. No block
    table ever references it, and attention masks strictly by positions /
    ``context_lens``, so garbage is never read (same invariant as the slot
    executor's position masking).

    Decode runs :func:`repro.kernels.ops.paged_decode_attention` over the
    pool + gathered block tables; prefill chunks run
    :func:`repro.kernels.ops.chunked_prefill_attention` over the request's
    gathered pages. ``use_pallas=None`` auto-selects the Pallas TPU kernels
    on TPU backends and the jnp reference path elsewhere (CPU CI).

    Supported model families: dense-attention stacks ("mlp" kind, e.g. the
    llama3 smoke arch) without sliding windows. MoE/SSM/hybrid/MLA/enc-dec
    and windowed layers stay on :class:`RealExecutor`.
    """

    # Pool rows are materialized for real — refuse the simulated device
    # HBM budgets (tens of thousands of blocks) that the builders default
    # to, and demand an explicit ``num_kv_blocks`` override instead.
    MAX_POOL_BLOCKS = 8192

    def __init__(self, model, params, *, use_pallas: Optional[bool] = None,
                 greedy: bool = True):
        cfg = model.cfg
        kind = model._stack_kind()
        if kind != "mlp" or model.is_mla or model.n_dense:
            raise NotImplementedError(
                f"PagedRealExecutor supports dense-attention stacks only "
                f"(got stack kind {kind!r}); use executor='real'")
        if cfg.enc_dec or cfg.embeddings_input:
            raise NotImplementedError(
                "PagedRealExecutor does not support encoder/decoder or "
                "embedding-input models; use executor='real'")
        if any(cfg.layer_window(i) for i in range(cfg.n_layers)):
            raise NotImplementedError(
                "PagedRealExecutor does not support sliding-window layers "
                "(paged decode attends the whole table); use executor='real'")
        self.model = model
        self.params = params
        self.cfg = cfg
        if use_pallas is None:
            use_pallas = jax.default_backend() == "tpu"
        self.use_pallas = bool(use_pallas)
        self.greedy = greedy
        self.buckets = BucketCache()
        self._engine = None
        self._allocator = None
        self.page: Optional[int] = None
        self.k_pool = None              # [L, P+1, page, Kv, D]
        self.v_pool = None
        self._trash: Optional[int] = None
        self._host_store: Dict[bytes, tuple] = {}   # chain hash -> (K, V)

    def compile_stats(self) -> Dict[str, int]:
        return self.buckets.compile_stats()

    # ------------------------------------------------------------------
    # engine attachment: pool sizing + allocator hooks
    # ------------------------------------------------------------------
    def attach_engine(self, engine) -> None:
        """Bind to the engine whose allocator drives this pool (called by
        ``Engine.__init__``). Sizes the physical pool from the engine's
        ``num_kv_blocks`` and registers the copy-on-write clone hook."""
        ecfg = engine.ecfg
        if ecfg.num_kv_blocks > self.MAX_POOL_BLOCKS:
            raise ValueError(
                f"paged executor would materialize {ecfg.num_kv_blocks} real "
                f"KV blocks (> {self.MAX_POOL_BLOCKS}); that default comes "
                "from the simulated device HBM budget — pass an explicit "
                "num_kv_blocks override (builders / ServeSpec "
                "--num-kv-blocks) sized for the real run")
        cfg = self.cfg
        self.page = ecfg.block_size
        self._trash = ecfg.num_kv_blocks
        shape = (self.model.n_stack, ecfg.num_kv_blocks + 1, self.page,
                 cfg.n_kv_heads, cfg.head_dim)
        self.k_pool = jnp.zeros(shape, self.model.dtype)
        self.v_pool = jnp.zeros(shape, self.model.dtype)
        self._engine = engine
        self._hook_allocator(engine.allocator)
        self._build_fns()

    def _hook_allocator(self, alloc) -> None:
        if alloc is self._allocator:
            return
        assert alloc.block_size == self.page, \
            "allocator block size changed under the paged pool"
        assert alloc.num_blocks <= self._trash, \
            "allocator grew past the physical pool"
        alloc.on_cow = self._clone_block
        # host-memory tier hooks: demotions copy the pool row out to host
        # DRAM before the allocator recycles it, promotions write it back
        alloc.on_demote = self._save_block
        alloc.on_promote = self._restore_block
        alloc.on_host_evict = self._drop_host
        self._allocator = alloc
        self._host_store: Dict[bytes, tuple] = {}

    def _save_block(self, blk: int, key: bytes) -> None:
        """Allocator demotion hook: the GPU row is about to be recycled —
        copy its K/V out to the modeled host store (fires while the row
        is still intact, before the block returns to the free list)."""
        self.buckets.record("host_demote", 1)
        self._host_store[key] = (np.asarray(self.k_pool[:, blk]),
                                 np.asarray(self.v_pool[:, blk]))

    def _restore_block(self, blk: int, key: bytes) -> None:
        """Allocator promotion hook: a host-resident chain got a prefix
        hit — write its K/V back into the newly assigned pool row."""
        k, v = self._host_store.pop(key)
        self.buckets.record("host_promote", 1)
        self.k_pool = self.k_pool.at[:, blk].set(jnp.asarray(k))
        self.v_pool = self.v_pool.at[:, blk].set(jnp.asarray(v))

    def _drop_host(self, key: bytes) -> None:
        """Allocator host-eviction hook (capacity pressure, or the GPU
        re-registered the same chain): forget the stored row."""
        self._host_store.pop(key, None)

    def _alloc(self):
        """The engine's CURRENT allocator (tests swap allocators to model
        migration; the pool follows, re-registering the CoW hook)."""
        self._hook_allocator(self._engine.allocator)
        return self._allocator

    def _req_id(self, slot: int) -> str:
        req = self._engine.slots[slot]
        assert req is not None, f"executor touched empty slot {slot}"
        return req.req_id

    def _clone_block(self, dst: int, src: int, n_tokens: int) -> None:
        """Allocator CoW hook: physically copy the first ``n_tokens`` rows
        of block ``src`` into ``dst`` (one [L, n, Kv, D] copy — the rest of
        ``dst`` is garbage until prefill/decode writes it)."""
        self.buckets.record("cow", 1)
        self.k_pool, self.v_pool = self._cow_fn(
            self.k_pool, self.v_pool, np.int32(dst), np.int32(src),
            np.int32(n_tokens))

    # ------------------------------------------------------------------
    # jitted forwards (built once per attach; XLA caches per bucket shape)
    # ------------------------------------------------------------------
    def _build_fns(self):
        from repro.kernels import ops
        from repro.models.layers import rmsnorm, swiglu
        from repro.models.rope import position_encode

        cfg = self.cfg
        model = self.model
        page = self.page
        h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        eps = cfg.norm_eps
        use_pallas = self.use_pallas

        def qkv(lp, x, positions):
            b, sq, _ = x.shape
            ap = lp["attn"]
            q = (x @ ap["wq"].astype(x.dtype)).reshape(b, sq, h, hd)
            k = (x @ ap["wk"].astype(x.dtype)).reshape(b, sq, kvh, hd)
            v = (x @ ap["wv"].astype(x.dtype)).reshape(b, sq, kvh, hd)
            if cfg.qk_norm:
                q = rmsnorm(q, ap["q_norm"], eps)
                k = rmsnorm(k, ap["k_norm"], eps)
            q = position_encode(q, positions, cfg)
            k = position_encode(k, positions, cfg)
            return q, k, v

        def write(pool, rows, write_idx):
            """Scatter token K/V rows into the flat pool view.
            pool [P+1, page, Kv, D]; rows [n, Kv, D]; write_idx [n] flat
            slots (block_id * page + offset; trash for padded lanes)."""
            flat = pool.reshape((-1,) + pool.shape[2:])
            flat = flat.at[write_idx].set(rows.astype(flat.dtype))
            return flat.reshape(pool.shape)

        def finish(x, params):
            x = rmsnorm(x, params["final_norm"], eps)
            head = (params["embed"].T if cfg.tie_embeddings
                    else params["head"])
            return x @ head.astype(x.dtype)

        def mlp(lp, x):
            h2 = rmsnorm(x, lp["ln2"], eps)
            return x + swiglu(h2, lp["mlp"]["w_gate"], lp["mlp"]["w_up"],
                              lp["mlp"]["w_down"])

        def prefill_fwd(params, k_pool, v_pool, tokens, positions,
                        write_idx, table, total):
            """tokens/positions [1, Cb] (-1-padded); write_idx [Cb] flat
            pool slots; table [Pb] page ids (trash-padded); total: scalar
            valid context length after this chunk."""
            x = params["embed"].astype(model.dtype)[tokens]
            s = table.shape[0] * page
            iota = jnp.arange(s, dtype=jnp.int32)
            kv_pos = jnp.where(iota < total, iota, -1)[None]

            def body(xc, xs):
                lp, kp, vp = xs
                hx = rmsnorm(xc, lp["ln1"], eps)
                q, k, v = qkv(lp, hx, positions)
                kp = write(kp, k[0], write_idx)
                vp = write(vp, v[0], write_idx)
                kg = kp[table].reshape(1, s, kvh, hd)
                vg = vp[table].reshape(1, s, kvh, hd)
                out = ops.chunked_prefill_attention(
                    q, kg, vg, positions, kv_pos, window=0,
                    use_pallas=use_pallas)
                xc = xc + (out.reshape(out.shape[:2] + (h * hd,))
                           @ lp["attn"]["wo"].astype(xc.dtype))
                return mlp(lp, xc), (kp, vp)

            x, (k_new, v_new) = jax.lax.scan(
                body, x, (params["layers"], k_pool, v_pool))
            return finish(x, params), k_new, v_new

        def decode_fwd(params, k_pool, v_pool, tokens, positions,
                       write_idx, tables, ctx_lens):
            """tokens/positions/write_idx [Bb]; tables [Bb, Pb] (trash-
            padded); ctx_lens [Bb] (0 for padded lanes)."""
            x = params["embed"].astype(model.dtype)[tokens][:, None]
            pos2 = positions[:, None]

            def body(xc, xs):
                lp, kp, vp = xs
                hx = rmsnorm(xc, lp["ln1"], eps)
                q, k, v = qkv(lp, hx, pos2)
                kp = write(kp, k[:, 0], write_idx)
                vp = write(vp, v[:, 0], write_idx)
                out = ops.paged_decode_attention(
                    q[:, 0], kp, vp, tables, ctx_lens,
                    use_pallas=use_pallas)
                xc = xc + (out.reshape(out.shape[0], 1, h * hd)
                           @ lp["attn"]["wo"].astype(xc.dtype))
                return mlp(lp, xc), (kp, vp)

            x, (k_new, v_new) = jax.lax.scan(
                body, x, (params["layers"], k_pool, v_pool))
            return finish(x, params)[:, 0], k_new, v_new

        def cow_fwd(k_pool, v_pool, dst, src, n):
            keep = jnp.arange(page) < n

            def clone(pool):
                sel = keep.reshape(1, page, 1, 1)
                merged = jnp.where(sel, pool[:, src], pool[:, dst])
                return pool.at[:, dst].set(merged)

            return clone(k_pool), clone(v_pool)

        def inject_fwd(k_pool, v_pool, k_rows, v_rows, dst_idx):
            """k/v_rows [L, n, Kv, D] payload tokens; dst_idx [n] flat
            pool slots (trash-padded)."""
            def put(pool, rows):
                flat = pool.reshape((pool.shape[0], -1) + pool.shape[3:])
                flat = flat.at[:, dst_idx].set(rows.astype(flat.dtype))
                return flat.reshape(pool.shape)

            return put(k_pool, k_rows), put(v_pool, v_rows)

        self._prefill_fn = jax.jit(prefill_fwd)
        self._decode_fn = jax.jit(decode_fwd)
        self._cow_fn = jax.jit(cow_fwd)
        self._inject_fn = jax.jit(inject_fwd)

    # ------------------------------------------------------------------
    # executor interface
    # ------------------------------------------------------------------
    def _flat_idx(self, table, pos: int) -> int:
        return table[pos // self.page] * self.page + pos % self.page

    def prefill_chunk(self, slot: int, tokens: np.ndarray, ctx_len: int,
                      completes: bool, enc_emb=None) -> Optional[int]:
        """Run one prefill chunk for ``slot`` through the request's block
        table. Returns the first output token if the prompt completes."""
        table = self._alloc().block_table(self._req_id(slot))
        page = self.page
        c = len(tokens)
        total = ctx_len + c
        cb = self.buckets.bucket(c, lo=16)
        pb = self.buckets.bucket(math.ceil(total / page), lo=4)
        assert len(table) * page >= total, "block table behind context"

        tok = np.zeros((1, cb), np.int32)
        tok[0, :c] = tokens
        pos = np.full((1, cb), -1, np.int32)
        pos[0, :c] = ctx_len + np.arange(c)
        widx = np.full((cb,), self._trash * page, np.int32)
        for j in range(c):
            widx[j] = self._flat_idx(table, ctx_len + j)
        tbl = np.full((pb,), self._trash, np.int32)
        take = min(len(table), pb)
        tbl[:take] = table[:take]

        self.buckets.record("prefill", cb, pb)
        logits, self.k_pool, self.v_pool = self._prefill_fn(
            self.params, self.k_pool, self.v_pool, tok, pos, widx, tbl,
            np.int32(total))
        if completes:
            return robust_greedy(logits[0, c - 1])
        return None

    def decode(self, slot_tokens: Dict[int, int],
               slot_lens: Dict[int, int]) -> Dict[int, int]:
        """One decode step over the active slots' block tables."""
        alloc = self._alloc()
        page = self.page
        slots = sorted(slot_tokens)
        tables = [alloc.block_table(self._req_id(s)) for s in slots]
        n = len(slots)
        bb = self.buckets.bucket(n, lo=4)
        pb = self.buckets.bucket(
            max(math.ceil((slot_lens[s] + 1) / page) for s in slots), lo=4)

        tok = np.zeros((bb,), np.int32)
        pos = np.zeros((bb,), np.int32)
        widx = np.full((bb,), self._trash * page, np.int32)
        tbl = np.full((bb, pb), self._trash, np.int32)
        ctx = np.zeros((bb,), np.int32)
        for i, s in enumerate(slots):
            p = slot_lens[s]
            tok[i] = slot_tokens[s]
            pos[i] = p
            widx[i] = self._flat_idx(tables[i], p)
            take = min(len(tables[i]), pb)
            tbl[i, :take] = tables[i][:take]
            ctx[i] = p + 1

        self.buckets.record("decode", bb, pb)
        logits, self.k_pool, self.v_pool = self._decode_fn(
            self.params, self.k_pool, self.v_pool, tok, pos, widx, tbl, ctx)
        return {s: robust_greedy(logits[i]) for i, s in enumerate(slots)}

    # ------------------------------------------------------------------
    # KV handoff: block-granular, sized by the partial prefill
    # ------------------------------------------------------------------
    def extract_kv(self, slot: int, upto: int):
        """PPI->CPI payload: only the ``ceil(upto / page)`` blocks covering
        the partial prefill travel (honest transfer accounting — the slot
        executor used to ship the full padded slot width)."""
        table = self._alloc().block_table(self._req_id(slot))
        nblk = math.ceil(upto / self.page)
        idx = jnp.asarray(table[:nblk], jnp.int32)
        return {"k_pages": self.k_pool[:, idx],
                "v_pages": self.v_pool[:, idx],
                "_upto": upto, "_page": self.page}

    def inject_kv(self, slot: int, payload, upto: int):
        """Scatter a transferred payload into the blocks this engine's
        allocator assigned. Positions the local prefix cache already
        covers (``allocator.shared_tokens``) are skipped: shared blocks
        are immutable, and their content is already resident."""
        alloc = self._alloc()
        assert payload["_page"] == self.page, \
            "page-size mismatch across a paged handoff"
        req_id = self._req_id(slot)
        table = alloc.block_table(req_id)
        shared = (alloc.shared_tokens(req_id)
                  if hasattr(alloc, "shared_tokens") else 0)
        p_upto = int(payload["_upto"])
        start = min(shared, p_upto)
        n = p_upto - start
        if n <= 0:
            return
        nb = self.buckets.bucket(n, lo=self.page)
        l_dim = self.k_pool.shape[0]
        kvh, hd = self.k_pool.shape[3], self.k_pool.shape[4]
        k_rows = np.zeros((l_dim, nb, kvh, hd), np.asarray(
            payload["k_pages"]).dtype)
        v_rows = np.zeros_like(k_rows)
        src_k = np.asarray(payload["k_pages"]).reshape(l_dim, -1, kvh, hd)
        src_v = np.asarray(payload["v_pages"]).reshape(l_dim, -1, kvh, hd)
        k_rows[:, :n] = src_k[:, start:p_upto]
        v_rows[:, :n] = src_v[:, start:p_upto]
        dst = np.full((nb,), self._trash * self.page, np.int32)
        for j in range(n):
            dst[j] = self._flat_idx(table, start + j)
        self.buckets.record("inject", nb)
        self.k_pool, self.v_pool = self._inject_fn(
            self.k_pool, self.v_pool, k_rows, v_rows, dst)

    def reset_slot(self, slot: int):
        """Nothing to scrub: validity lives in the allocator's tables and
        per-request context lengths, not in pool contents."""
