"""Executors: the compute backends of the serving engines.

``RealExecutor`` runs actual JAX forwards on a slot-based cache (functional
correctness at reduced scale — the engine's tokens must match a monolithic
run bit-for-bit). ``NullExecutor`` skips compute entirely (scheduling +
timing studies at paper scale — Tables 2-3, Fig. 4). Both sit behind the
same interface, so the scheduler/balancer code under test is identical.

Slot-garbage invariant (why batched forwards are safe): forwards always run
over ALL slots; rows of slots not participating this iteration write
garbage K/V at indices beyond their valid region. Validity is defined
exclusively by host-managed ``kv_positions``, which only ever advance for
participating slots, and any later advance overwrites those indices with
real K/V first. Freed slots reset their position row to -1.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp


def _pow2_bucket(n: int, lo: int = 16) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


# Margin for deterministic greedy tie-breaking. XLA CPU results carry small
# environment-dependent jitter (heap alignment changes SIMD reduction tails,
# ~1e-4 with fp32); plain argmax then flips near-ties and the token stream
# cascades. Reproducible serving instead picks the LOWEST token id among all
# logits within this margin of the max — stable under jitter << margin.
GREEDY_TIE_MARGIN = 0.05


def robust_greedy(logits_row) -> int:
    row = np.asarray(logits_row, np.float32)
    top = row.max()
    return int(np.nonzero(row >= top - GREEDY_TIE_MARGIN)[0][0])


class NullExecutor:
    """No compute; emits deterministic dummy tokens."""

    def __init__(self):
        self._counter = 0

    def prefill_chunk(self, slot, tokens, ctx_len, completes, enc_emb=None):
        if completes:
            self._counter += 1
            return self._counter
        return None

    def decode(self, slot_tokens: Dict[int, int], slot_lens: Dict[int, int]):
        out = {}
        for s in slot_tokens:
            self._counter += 1
            out[s] = self._counter
        return out

    def extract_kv(self, slot, upto):
        return {"_null": upto}

    def inject_kv(self, slot, payload, upto):
        pass

    def reset_slot(self, slot):
        pass


class RealExecutor:
    """JAX execution over a slot-based unified cache with host-managed
    positions. Chunk lengths are padded to power-of-two buckets to bound
    recompilation."""

    def __init__(self, model, params, *, max_slots: int, s_kv: int,
                 chunk_pad: Optional[int] = None, greedy: bool = True):
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.max_slots = max_slots
        self.s_kv = s_kv
        # Fixed chunk width: padding every prefill chunk to one width keeps
        # all forwards shape-identical, so XLA reductions are bit-identical
        # across schedules (token streams then match any same-width oracle).
        self.chunk_pad = chunk_pad
        self.cache = model.init_cache(max_slots, s_kv)
        self.pos = np.full((max_slots, s_kv), -1, np.int32)   # host positions
        self.lens = np.zeros((max_slots,), np.int32)          # host lengths
        self._fwd = jax.jit(
            lambda p, inp, cache, cl, pos, kvp, dec: model.forward(
                p, inp, cache, cl, positions=pos, kv_positions=kvp,
                decode=dec),
            static_argnames=("dec",))
        self._enc_dec = self.cfg.enc_dec

    # ------------------------------------------------------------------
    def _run(self, inputs, positions, decode: bool, active_mask=None,
             enc_out=None):
        kvp = jnp.asarray(self.pos)
        cl = jnp.asarray(self.lens)
        if self._enc_dec:
            logits, new_cache, _ = self.model.forward(
                self.params, jnp.asarray(inputs), self.cache, cl,
                positions=jnp.asarray(positions), kv_positions=kvp,
                enc_out=enc_out, decode=decode)
        else:
            logits, new_cache, _ = self._fwd(
                self.params, jnp.asarray(inputs), self.cache, cl,
                jnp.asarray(positions), kvp, decode)
        # Attention-cache garbage written to inactive slots is masked by
        # positions, but recurrent SSM state is not — restore it for slots
        # that did not participate in this forward.
        if active_mask is not None and "h" in new_cache.get("stack", {}):
            m = jnp.asarray(active_mask)
            old, new = self.cache["stack"], dict(new_cache["stack"])
            for key in ("h", "conv"):
                sel = m.reshape((1, -1) + (1,) * (old[key].ndim - 2))
                new[key] = jnp.where(sel, new[key], old[key])
            new_cache = dict(new_cache)
            new_cache["stack"] = new
        self.cache = new_cache
        return logits

    def prefill_chunk(self, slot: int, tokens: np.ndarray, ctx_len: int,
                      completes: bool, enc_emb=None) -> Optional[int]:
        """Run one prefill chunk for `slot`. Returns first token if the
        prompt completes with this chunk."""
        c = len(tokens)
        if self.chunk_pad and c <= self.chunk_pad:
            cb = self.chunk_pad
        else:
            cb = _pow2_bucket(c)
        inputs = np.zeros((self.max_slots, cb), np.int32)
        positions = np.full((self.max_slots, cb), -1, np.int32)
        inputs[slot, :c] = tokens
        positions[slot, :c] = ctx_len + np.arange(c)
        # mark new positions valid for this slot (host-side)
        idx = (ctx_len + np.arange(c)) % self.s_kv
        self.pos[slot, idx] = ctx_len + np.arange(c)
        if self._enc_dec and enc_emb is not None:
            # run the encoder for this request only and install its
            # cross-KV into the slot (never clobbering other slots)
            assert enc_emb.shape[0] == self.cache["cross_k"].shape[2], (
                "encoder input length must match the cross-KV cache "
                f"({enc_emb.shape[0]} vs {self.cache['cross_k'].shape[2]}); "
                "pad/crop the frontend-stub embeddings to enc_seq_len")
            enc_out = self.model.encode(self.params,
                                        jnp.asarray(enc_emb)[None])
            ck, cv = self.model.compute_cross_kv(self.params, enc_out)
            cache = dict(self.cache)
            cache["cross_k"] = cache["cross_k"].at[:, slot].set(ck[:, 0])
            cache["cross_v"] = cache["cross_v"].at[:, slot].set(cv[:, 0])
            self.cache = cache
        mask = np.zeros((self.max_slots,), bool)
        mask[slot] = True
        logits = self._run(inputs, positions, decode=False, active_mask=mask)
        self.lens[slot] = ctx_len + c
        if completes:
            return robust_greedy(logits[slot, c - 1])
        return None

    def decode(self, slot_tokens: Dict[int, int],
               slot_lens: Dict[int, int]) -> Dict[int, int]:
        """One decode step for the given slots. Returns slot -> next token."""
        inputs = np.zeros((self.max_slots, 1), np.int32)
        positions = np.full((self.max_slots, 1), -1, np.int32)
        mask = np.zeros((self.max_slots,), bool)
        for s, tok in slot_tokens.items():
            inputs[s, 0] = tok
            positions[s, 0] = slot_lens[s]
            self.pos[s, slot_lens[s] % self.s_kv] = slot_lens[s]
            mask[s] = True
        logits = self._run(inputs, positions, decode=True, active_mask=mask)
        out = {}
        for s in slot_tokens:
            out[s] = robust_greedy(logits[s, 0])
            self.lens[s] = slot_lens[s] + 1
        return out

    # ------------------------------------------------------------------
    def extract_kv(self, slot: int, upto: int):
        """Pull one slot's cache slices (the PPI->CPI payload)."""
        payload = {"stack": jax.tree.map(lambda a: a[:, slot],
                                         self.cache["stack"])}
        if "dense" in self.cache:
            payload["dense"] = jax.tree.map(lambda a: a[:, slot],
                                            self.cache["dense"])
        for k in ("cross_k", "cross_v"):
            if k in self.cache:
                payload[k] = self.cache[k][:, slot]
        payload["_upto"] = upto
        return payload

    def inject_kv(self, slot: int, payload, upto: int):
        """Install a transferred payload into `slot` and mark [0, upto) valid."""
        def put(dst, src):
            return dst.at[:, slot].set(src)

        cache = dict(self.cache)
        cache["stack"] = jax.tree.map(put, self.cache["stack"],
                                      payload["stack"])
        if "dense" in payload:
            cache["dense"] = jax.tree.map(put, self.cache["dense"],
                                          payload["dense"])
        for k in ("cross_k", "cross_v"):
            if k in payload:
                cache[k] = cache[k].at[:, slot].set(payload[k])
        self.cache = cache
        self.pos[slot, :] = -1
        self.pos[slot, :upto] = np.arange(upto)
        self.lens[slot] = upto

    def reset_slot(self, slot: int):
        self.pos[slot, :] = -1
        self.lens[slot] = 0
        # Attention-cache garbage is masked out by positions, but recurrent
        # state (SSM/hybrid) has no positional validity — zero it explicitly.
        stack = self.cache["stack"]
        if "h" in stack:
            cache = dict(self.cache)
            new_stack = dict(stack)
            for key in ("h", "conv"):
                new_stack[key] = stack[key].at[:, slot].set(0)
            cache["stack"] = new_stack
            self.cache = cache
