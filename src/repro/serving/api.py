"""Online serving API: declarative :class:`ServeSpec` + request-level
:class:`InferenceService`.

Cronus is an *online* system — requests arrive continuously and TTFT/TBT
tail latency is the product — but until this module the only public
surface was offline: thread kwargs through five builders
(``build_cronus`` / ``build_dp`` / ``build_pp`` / ``build_cluster`` /
``build_system``) and call ``run(full_trace)``. This module replaces that
with the two layers production stacks expose:

``ServeSpec``
    One frozen dataclass describing the whole deployment — model arch,
    pair vs cluster topology, router, scheduling policy, prefix caching,
    executor, KV sizing. JSON round-trippable (``to_dict``/``from_dict``),
    argparse round-trippable (``add_cli_args``/``from_cli``), validated at
    construction, and ``build()`` materialises it into a running service,
    subsuming the kwarg plumbing of the five builders.

``InferenceService``
    The online facade over :class:`~repro.cluster.runtime.ClusterRuntime`:
    ``submit(req) -> RequestHandle`` (streaming via ``handle.tokens()``,
    driven by the per-token emission hook in ``Engine.step``),
    ``handle.cancel()`` (frees slots/KV blocks mid-flight, records the
    ``cancelled`` terminal metric), ``step_until(t)`` incremental
    simulation, and ``drain()``. The legacy batch surface survives as the
    thin wrapper ``run(requests)`` = submit-all + drain, bit-identical on
    metrics to the builders' ``system.run(trace)``.

Example::

    spec = ServeSpec(cluster="2xcronus:A100+A10,4xworker:A10",
                     router="least_loaded", sched_policy="sarathi")
    service = spec.build()
    handle = service.submit(Request("r0", prompt, output_len=64))
    for token, t in handle.tokens():      # advances simulated time
        ...
    metrics = service.drain()
"""
from __future__ import annotations

import dataclasses
import json
from collections import deque
from typing import Callable, Deque, Dict, Iterator, List, Optional, Tuple

from repro.cluster.router import ROUTERS, Router, RoundRobinRouter, make_router
from repro.cluster.runtime import (ClusterRuntime, Endpoint, WorkerEndpoint,
                                   check_requests_fresh)
from repro.cluster.topology import build_cluster, parse_cluster_spec
from repro.configs import ARCH_IDS, get_config
from repro.core.metrics import RequestMetrics, aggregate
from repro.core.request import ReqState, Request
from repro.scheduling import SCHEDULERS
from repro.serving.hardware import DEVICES
from repro.serving.simulator import APPROACHES, build_system
from repro.workloads.arrivals import parse_arrival

EXECUTORS = ("null", "real", "paged")


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """Declarative description of one serving deployment — the single
    source of truth ``launch/serve.py`` and the examples build from.

    Topology is either a single heterogeneous pair (``approach`` over the
    ``hi``/``lo`` devices — one of ``cronus | dp | pp | disagg_hl |
    disagg_lh``) or a whole cluster (``cluster`` DSL string such as
    ``"2xcronus:A100+A10,4xworker:A10@sjf"``, which overrides
    ``approach``/``hi``/``lo``).

    ``router=None`` picks the approach-appropriate default: the weighted
    round-robin of the paper's DP baseline, plain round-robin for
    single-endpoint topologies, least-loaded for clusters — exactly what
    the legacy ``system.run`` paths used, so a default spec reproduces
    their metrics bit-for-bit.

    ``executor="real"`` runs real JAX compute (reduced configs only) and
    needs ``s_kv`` — the per-slot KV capacity in tokens, normally the max
    ``input_len + output_len`` of the workload plus headroom.
    ``executor="paged"`` also runs real compute but stores KV in a block
    pool indexed by the engine's block tables (paged attention), so
    prefix caching / ``@cache`` work on real compute; its pool size is
    ``num_kv_blocks`` (default ``max_slots * ceil(s_kv / block_size)``).
    """

    arch: str = "llama3-8b"
    smoke: bool = False                   # reduced model config
    approach: str = "cronus"              # one of APPROACHES (pair mode)
    hi: str = "A100"                      # high-end device (pair mode)
    lo: str = "A10"                       # low-end device (pair mode)
    cluster: Optional[str] = None         # topology DSL; overrides approach
    router: Optional[str] = None          # None = approach-appropriate
    sched_policy: str = "fcfs"            # iteration-level batch policy
    prefix_cache: bool = False            # shared-prefix KV reuse (null/paged)
    executor: str = "null"                # "null" (sim) | "real" | "paged"
    max_slots: int = 256                  # resident-request limit per engine
    block_size: int = 16                  # KV block granularity
    max_batched_tokens: int = 512         # chunked-prefill token budget
    s_kv: Optional[int] = None            # real executor: KV tokens per slot
    chunk_pad: Optional[int] = None       # real executor: pad chunks (jit)
    num_kv_blocks: Optional[int] = None   # paged executor: KV pool blocks
    host_kv_blocks: int = 0               # host-memory cache tier (0 = off)
    # open-loop arrival process for workload driving (repro.workloads):
    # "fixed:I" | "poisson:RATE" | "burst:RATE[:B[:ON]]" | "ramp:LO:HI[:P]".
    # None = closed-loop trace replay (the historical behaviour).
    arrival: Optional[str] = None
    # elastic autoscaling (repro.autoscale): policy spec string such as
    # "slo:goodput>=0.9:cooldown=5", plus the idle-device inventory the
    # autoscaler may attach ("A100:1,A10:4"). None = fixed fleet.
    autoscale: Optional[str] = None
    inventory: Optional[str] = None

    def __post_init__(self):
        self.validate()

    # ------------------------------------------------------------------
    # validation
    # ------------------------------------------------------------------
    def validate(self) -> None:
        """Refuse malformed or contradictory specs with one-line errors
        (the full matrix is documented in docs/OPERATIONS.md)."""
        if self.arch not in ARCH_IDS:
            raise ValueError(f"unknown arch {self.arch!r}; "
                             f"choose from {ARCH_IDS}")
        if self.cluster is not None:
            parse_cluster_spec(self.cluster)     # raises ValueError on DSL errors
        else:
            if self.approach not in APPROACHES:
                raise ValueError(f"unknown approach {self.approach!r}; "
                                 f"choose from {APPROACHES}")
            for dev in (self.hi, self.lo):
                if dev not in DEVICES:
                    raise ValueError(f"unknown device {dev!r}; "
                                     f"choose from {sorted(DEVICES)}")
        if self.router is not None and self.router not in ROUTERS:
            raise ValueError(f"unknown router {self.router!r}; "
                             f"choose from {sorted(ROUTERS)}")
        if self.sched_policy not in SCHEDULERS:
            raise ValueError(f"unknown sched policy {self.sched_policy!r}; "
                             f"choose from {sorted(SCHEDULERS)}")
        if self.executor not in EXECUTORS:
            raise ValueError(f"unknown executor {self.executor!r}; "
                             f"choose from {EXECUTORS}")
        if self.executor == "real" and (
                self.prefix_cache or "@cache" in (self.cluster or "")):
            raise ValueError(
                "prefix caching (prefix_cache / '@cache' node suffix) "
                "models KV reuse at the block-table level; the "
                "RealExecutor's slot cache cannot serve cached prefixes "
                "— use executor='paged', whose block-pool KV serves "
                "cache hits on real compute")
        for name in ("max_slots", "block_size", "max_batched_tokens"):
            if getattr(self, name) < 1:
                raise ValueError(f"{name} must be >= 1")
        if (self.cluster is None and self.approach in ("dp", "pp")
                and self.max_batched_tokens
                != self._default("max_batched_tokens")):
            # refuse rather than silently ignore: these baselines pin the
            # paper's §5.1 per-engine budgets (dp: 512 high / 256 low,
            # pp: 512) inside build_dp/build_pp
            raise ValueError(
                f"approach {self.approach!r} uses the paper's fixed "
                "per-engine token budgets (dp: 512/256, pp: 512); "
                "max_batched_tokens applies to cronus/disagg pairs and "
                "--cluster topologies")
        if self.s_kv is not None and self.s_kv < 1:
            raise ValueError("s_kv must be >= 1")
        if self.num_kv_blocks is not None:
            if self.num_kv_blocks < 1:
                raise ValueError("num_kv_blocks must be >= 1")
            if self.executor != "paged":
                raise ValueError(
                    "num_kv_blocks sizes the paged executor's real KV "
                    "pool; with executor="
                    f"{self.executor!r} the pool is device-HBM-derived "
                    "(set executor='paged')")
        if self.host_kv_blocks < 0:
            raise ValueError("host_kv_blocks must be >= 0")
        if self.host_kv_blocks > 0 and not (
                self.prefix_cache or "@cache" in (self.cluster or "")):
            raise ValueError(
                "host_kv_blocks adds a host-memory tier *behind the "
                "prefix cache* (demoted refcount-0 prefix blocks); it "
                "does nothing without prefix caching — set prefix_cache "
                "or an '@cache' node suffix")
        if self.arrival is not None:
            parse_arrival(self.arrival)   # raises ValueError on bad specs
        if self.autoscale is not None:
            from repro.autoscale import DeviceInventory, parse_autoscale
            parse_autoscale(self.autoscale)  # raises ValueError on bad specs
            if self.executor in ("real", "paged"):
                raise ValueError(
                    "autoscale builds new endpoints on the fly; the "
                    "real executors' compiled model state cannot be "
                    "provisioned mid-run, so autoscaling is "
                    "simulation-only")
            if (self.inventory is None
                    or DeviceInventory.parse(self.inventory).total == 0):
                raise ValueError(
                    "autoscale needs a non-empty device inventory to "
                    "scale into — with a fixed endpoint set and an empty "
                    "rack there is nothing to attach "
                    "(set inventory='A100:1,A10:4'-style)")
        elif self.inventory is not None:
            raise ValueError(
                "inventory without autoscale does nothing — idle devices "
                "are only consumed by the autoscaler (set autoscale, "
                "e.g. 'slo:goodput>=0.9')")

    # ------------------------------------------------------------------
    # serialization (JSON round-trip)
    # ------------------------------------------------------------------
    def to_dict(self) -> Dict:
        """The spec as a plain JSON-ready dict."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "ServeSpec":
        """Inverse of :meth:`to_dict`; unknown keys are refused."""
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown ServeSpec keys {sorted(unknown)}; "
                             f"known: {sorted(known)}")
        return cls(**d)

    @classmethod
    def from_json_file(cls, path: str) -> "ServeSpec":
        """Load a spec from a JSON file (``serve.py --spec``)."""
        with open(path) as f:
            return cls.from_dict(json.load(f))

    def replace(self, **changes) -> "ServeSpec":
        """A copy with ``changes`` applied (re-validated)."""
        return dataclasses.replace(self, **changes)

    @classmethod
    def from_plan(cls, plan, rank: int = 0, rate: Optional[float] = None,
                  **overrides) -> "ServeSpec":
        """A spec that serves a planner recommendation
        (:class:`repro.autotopo.PlanResult` or its ``to_dict()`` form):
        the ranked candidate's canonical cluster + router, the probe-time
        spec knobs (``spec_kw``), and the planned workload's arrival
        process at ``rate`` (default: the candidate's measured capacity)
        — so ``serve.py --plan ... --serve-best`` runs the deployment
        under exactly the conditions the planner scored it at.
        ``overrides`` win over everything."""
        from repro.autotopo import parse_workload
        d = plan.to_dict() if hasattr(plan, "to_dict") else plan
        ranked = d.get("ranked", [])
        if not ranked:
            raise ValueError("cannot build a spec from an empty plan")
        if not 0 <= rank < len(ranked):
            raise ValueError(f"plan has {len(ranked)} ranked candidates; "
                             f"rank {rank} is out of range")
        best = ranked[rank]
        if rate is None:
            rate = best["capacity_qps"]
        workload = parse_workload(d["workload"])
        kw = dict(d.get("spec_kw", {}))
        kw.update(cluster=best["cluster"], router=best["router"],
                  arrival=workload.arrival_spec(rate) if rate > 0 else None)
        kw.update(overrides)
        return cls(**kw)

    # ------------------------------------------------------------------
    # argparse round-trip (serve.py's system flags live HERE so the CLI
    # can never drift from the spec — see tests/test_api.py)
    # ------------------------------------------------------------------
    @classmethod
    def add_cli_args(cls, ap) -> None:
        """Generate one CLI flag per spec field (serve.py's system
        flags; a test asserts the CLI covers every field)."""
        g = ap.add_argument_group(
            "serving spec", "system topology and policies (ServeSpec)")
        g.add_argument("--arch", default=cls._default("arch"),
                       choices=ARCH_IDS)
        g.add_argument("--smoke", action="store_true",
                       help="use the reduced model config")
        g.add_argument("--approach", default=cls._default("approach"),
                       choices=APPROACHES)
        g.add_argument("--hi", default=cls._default("hi"),
                       choices=sorted(DEVICES))
        g.add_argument("--lo", default=cls._default("lo"),
                       choices=sorted(DEVICES))
        g.add_argument("--cluster", default=None,
                       help="cluster spec, e.g. "
                            "'2xcronus:A100+A10,4xworker:A10' "
                            "(overrides --approach/--hi/--lo)")
        g.add_argument("--router", default=None, choices=sorted(ROUTERS),
                       help="cluster request router (default: approach-"
                            "appropriate — weighted RR for dp, "
                            "least-loaded for --cluster)")
        g.add_argument("--sched-policy", default=cls._default("sched_policy"),
                       choices=sorted(SCHEDULERS),
                       help="iteration-level batch-composition policy "
                            "(fcfs = seed-identical); per-endpoint "
                            "override via '@policy' in --cluster")
        g.add_argument("--prefix-cache", action="store_true",
                       help="shared-prefix KV reuse (null or paged "
                            "executor; per-endpoint override via "
                            "'@cache')")
        g.add_argument("--real", action="store_true",
                       help="real JAX execution (executor='real'; use "
                            "with --smoke and a scaled trace)")
        g.add_argument("--executor", default=None, choices=EXECUTORS,
                       help="compute backend: null (simulated), real "
                            "(per-slot dense KV), paged (block-pool KV "
                            "driven by the engine's block tables; "
                            "prefix-cache capable). Overrides --real")
        g.add_argument("--max-slots", type=int, default=None,
                       help="resident-request limit per engine "
                            "(default 256; 16 with --real)")
        g.add_argument("--block-size", type=int, default=None,
                       help="KV block granularity (default 16; 4 with "
                            "--real)")
        g.add_argument("--max-batched-tokens", type=int,
                       default=cls._default("max_batched_tokens"),
                       help="chunked-prefill token budget per iteration")
        g.add_argument("--s-kv", type=int, default=None,
                       help="real executor: KV capacity per slot in "
                            "tokens (default: derived from the trace)")
        g.add_argument("--chunk-pad", type=int, default=None,
                       help="real executor: pad prefill chunks to this "
                            "multiple (fewer jit recompiles)")
        g.add_argument("--num-kv-blocks", type=int, default=None,
                       help="paged executor: KV pool size in blocks per "
                            "engine (default: max_slots * "
                            "ceil(s_kv / block_size))")
        g.add_argument("--host-kv-blocks", type=int,
                       default=cls._default("host_kv_blocks"),
                       help="host-memory KV cache tier in blocks per "
                            "engine: refcount-0 prefix blocks demote to "
                            "host DRAM and promote back on a hit, PCIe "
                            "cost charged (needs --prefix-cache or "
                            "'@cache'; per-node override via '@host')")
        g.add_argument("--arrival", default=cls._default("arrival"),
                       metavar="PROC",
                       help="open-loop arrival process: fixed:I | "
                            "poisson:RATE | burst:RATE[:BURSTINESS"
                            "[:MEAN_ON]] | ramp:LO:HI[:PERIOD] "
                            "(default: closed-loop replay at --interval)")
        g.add_argument("--autoscale", default=cls._default("autoscale"),
                       metavar="POLICY",
                       help="elastic autoscaling policy, e.g. "
                            "'slo:goodput>=0.9:cooldown=5' "
                            "(default: fixed fleet; needs --inventory)")
        g.add_argument("--inventory", default=cls._default("inventory"),
                       metavar="DEVICES",
                       help="idle devices the autoscaler may attach, "
                            "e.g. 'A100:1,A10:4'")

    @classmethod
    def from_cli(cls, args) -> "ServeSpec":
        """Build a spec from parsed CLI args (inverse of
        :meth:`add_cli_args`, with the --real back-compat sizing)."""
        executor = getattr(args, "executor", None) or (
            "real" if getattr(args, "real", False) else "null")
        # real-compute runs keep the historical CPU-scale defaults unless
        # overridden (--real is the back-compat spelling of executor=real)
        max_slots = args.max_slots if args.max_slots is not None else (
            16 if executor != "null" else cls._default("max_slots"))
        block_size = args.block_size if args.block_size is not None else (
            4 if executor != "null" else cls._default("block_size"))
        return cls(arch=args.arch, smoke=args.smoke, approach=args.approach,
                   hi=args.hi, lo=args.lo, cluster=args.cluster,
                   router=args.router, sched_policy=args.sched_policy,
                   prefix_cache=args.prefix_cache, executor=executor,
                   max_slots=max_slots, block_size=block_size,
                   max_batched_tokens=args.max_batched_tokens,
                   s_kv=args.s_kv, chunk_pad=args.chunk_pad,
                   num_kv_blocks=getattr(args, "num_kv_blocks", None),
                   host_kv_blocks=getattr(args, "host_kv_blocks", 0),
                   arrival=args.arrival, autoscale=args.autoscale,
                   inventory=args.inventory)

    @classmethod
    def _default(cls, field: str):
        return cls.__dataclass_fields__[field].default

    # ------------------------------------------------------------------
    # materialisation
    # ------------------------------------------------------------------
    def build(self, model=None, params=None) -> "InferenceService":
        """Build engines, endpoints and router per this spec and wrap
        them in an online :class:`InferenceService`.

        ``executor="real"`` accepts a pre-built ``model``/``params`` pair
        (otherwise the model is built and initialised here) and requires
        ``s_kv``.
        """
        cfg = get_config(self.arch, smoke=self.smoke)
        factory = self._executor_factory(cfg, model, params)
        num_kv_blocks = self.effective_num_kv_blocks()
        if self.cluster is not None:
            system = build_cluster(
                cfg, self.cluster, router=self.router or "least_loaded",
                executor_factory=factory, max_slots=self.max_slots,
                block_size=self.block_size,
                max_batched_tokens=self.max_batched_tokens,
                sched_policy=self.sched_policy,
                prefix_cache=self.prefix_cache,
                num_kv_blocks=num_kv_blocks,
                host_kv_blocks=self.host_kv_blocks, executor=self.executor)
            service = InferenceService(system.endpoints, system.router,
                                       spec=self, cfg=cfg, system=system)
        else:
            system = build_system(
                self.approach, cfg, DEVICES[self.hi], DEVICES[self.lo],
                executor_factory=factory, max_slots=self.max_slots,
                block_size=self.block_size,
                max_batched_tokens=self.max_batched_tokens,
                sched_policy=self.sched_policy,
                prefix_cache=self.prefix_cache,
                num_kv_blocks=num_kv_blocks,
                host_kv_blocks=self.host_kv_blocks, executor=self.executor)
            endpoints, router = self._pair_endpoints(system)
            service = InferenceService(endpoints, router, spec=self,
                                       cfg=cfg, system=system)
        # how the autoscaler builds scale-up endpoints that match the
        # fleet's engine-level policies
        service.build_kw = dict(
            executor_factory=factory, max_slots=self.max_slots,
            block_size=self.block_size,
            max_batched_tokens=self.max_batched_tokens,
            sched_policy=self.sched_policy, prefix_cache=self.prefix_cache,
            num_kv_blocks=num_kv_blocks,
            host_kv_blocks=self.host_kv_blocks, executor=self.executor)
        if self.autoscale is not None:
            from repro.autoscale import (Autoscaler, DeviceInventory,
                                         parse_autoscale)
            service.attach_autoscaler(Autoscaler(
                DeviceInventory.parse(self.inventory),
                policy=parse_autoscale(self.autoscale)))
        return service

    def _pair_endpoints(self, system) -> Tuple[List[Endpoint], Router]:
        """Endpoint + router wiring for the five single-pair approaches —
        identical to what each system's legacy ``run()`` assembles, so
        default-spec services reproduce their metrics bit-for-bit."""
        if self.approach == "dp":
            endpoints: List[Endpoint] = system.endpoints()
            default: Router = RoundRobinRouter(weights=system.weights)
        elif self.approach == "pp":
            endpoints = [WorkerEndpoint(system.engine.name, system.engine,
                                        queue_cap=None)]
            default = RoundRobinRouter()
        else:                       # cronus / disagg_hl / disagg_lh
            endpoints = [system.endpoint()]
            default = RoundRobinRouter()
        router = make_router(self.router) if self.router else default
        return endpoints, router

    def effective_num_kv_blocks(self) -> Optional[int]:
        """KV pool size handed to the builders: the explicit override, or
        for ``executor="paged"`` a pool that matches the slot executor's
        aggregate capacity (``max_slots * ceil(s_kv / block_size)``) so
        slot and paged runs admit identical batches by default. ``None``
        (simulated / slot paths with no override) keeps each engine's
        device-HBM-derived budget."""
        if self.num_kv_blocks is not None:
            return self.num_kv_blocks
        if self.executor == "paged":
            if self.s_kv is None:
                raise ValueError(
                    "executor='paged' needs s_kv (to size the default "
                    "num_kv_blocks pool) or an explicit num_kv_blocks")
            return self.max_slots * -(-self.s_kv // self.block_size)
        return None

    def _executor_factory(self, cfg, model, params) -> Callable:
        if self.executor == "null":
            from repro.core.executor import NullExecutor
            return lambda role: NullExecutor()
        if self.executor == "real" and self.s_kv is None:
            raise ValueError(
                "executor='real' needs s_kv (per-slot KV capacity in "
                "tokens) — spec.replace(s_kv=max context + headroom)")
        from repro.core.executor import PagedRealExecutor, RealExecutor
        if model is None:
            import jax
            from repro.models import build_model
            model = build_model(cfg, exact_moe=True)
            params = model.init_params(jax.random.PRNGKey(0))
        spec = self

        if self.executor == "paged":
            self.effective_num_kv_blocks()   # validate sizing up front

            def factory(role):
                """Fresh paged executor per engine (own block pool)."""
                # one executor per engine: each owns its own block pool,
                # sized from EngineConfig.num_kv_blocks at attach_engine
                return PagedRealExecutor(model, params)
            return factory

        def factory(role):
            """Slot executor; the PPI keeps the paper's 2-slot cap."""
            return RealExecutor(
                model, params,
                max_slots=2 if role == "ppi" else spec.max_slots,
                s_kv=spec.s_kv, chunk_pad=spec.chunk_pad)
        return factory


# ---------------------------------------------------------------------------
# the online facade
# ---------------------------------------------------------------------------

class RequestHandle:
    """Live view of one submitted request: stream its tokens, wait for
    its result, or cancel it mid-flight. Obtained from
    :meth:`InferenceService.submit` — never constructed directly."""

    def __init__(self, request: Request, service: "InferenceService"):
        self.request = request
        self._service = service
        self._streaming = False        # buffer only once tokens() is asked
        self._stream: Deque[Tuple[int, float]] = deque()

    @property
    def req_id(self) -> str:
        """The underlying request's id."""
        return self.request.req_id

    @property
    def done(self) -> bool:
        """Whether the request finished (not cancelled)."""
        return self.request.state is ReqState.FINISHED

    @property
    def cancelled(self) -> bool:
        """Whether the request was cancelled."""
        return self.request.metrics.cancelled

    @property
    def status(self) -> str:
        """``queued | running | finished | cancelled`` (coarse view of
        the engine-level request state)."""
        if self.cancelled:
            return "cancelled"
        if self.done:
            return "finished"
        if self.request.state is ReqState.WAITING and self.request.slot is None:
            return "queued"
        return "running"

    def _subscribe(self) -> None:
        """Start buffering live emissions, seeding the stream with every
        token already delivered. Emitted history = tokens folded into the
        prompt by preemption-recompute (they sit past the original
        ``metrics.input_len``) + the current ``generated`` list, with one
        timestamp each in ``first_token_time`` + ``token_times`` — exact
        under every policy, so late subscribers miss nothing. Nothing is
        buffered for handles nobody streams (batch ``run`` stays O(1) in
        token memory)."""
        self._streaming = True
        m = self.request.metrics
        if m.first_token_time is None:
            return
        hist = (list(self.request.prompt[m.input_len:])
                + list(self.request.generated))
        times = [m.first_token_time] + list(m.token_times)
        self._stream.extend(zip(hist, times))

    def tokens(self) -> Iterator[Tuple[int, float]]:
        """Stream ``(token_id, sim_time)`` pairs as the request generates
        them, advancing the whole cluster's simulated time as needed.
        Ends after the final token, or immediately on cancellation."""
        if not self._streaming:
            self._subscribe()
        while True:
            while self._stream:
                yield self._stream.popleft()
            if self.done or self.cancelled:
                return
            if not self._service.step():
                return      # cluster stalled with nothing left to do

    def result(self) -> RequestMetrics:
        """Block (in simulated time) until this request finishes or is
        cancelled; returns its metrics."""
        while not (self.done or self.cancelled):
            if not self._service.step():
                break
        return self.request.metrics

    def cancel(self) -> bool:
        """Abort mid-flight: frees the request's slot and KV blocks
        wherever it lives (pending, queued, prefilling on a PPI, in KV
        transit, or decoding) and records the ``cancelled`` terminal
        state. False if already finished/cancelled."""
        return self._service.cancel(self)


class InferenceService:
    """Request-level online facade over a built cluster.

    Drives :class:`~repro.cluster.runtime.ClusterRuntime` incrementally:
    ``submit`` enqueues work at its ``arrival`` time, ``step`` executes
    one event-loop round, ``step_until(t)`` advances simulated time,
    ``drain`` runs everything to completion. ``run(requests)`` is the
    legacy batch surface as a thin wrapper (submit-all + drain) and is
    bit-identical on metrics to the builders' ``system.run(trace)``.
    """

    def __init__(self, endpoints: List[Endpoint], router: Router, *,
                 spec: Optional[ServeSpec] = None, cfg=None, system=None):
        self.runtime = ClusterRuntime(endpoints, router)
        self.spec = spec
        self.cfg = cfg
        self.system = system          # the underlying builder product
        self._pending: Deque[Request] = deque()
        self._handles: Dict[str, RequestHandle] = {}
        self._n_cancelled = 0
        self._autoscaler = None
        self.build_kw: Dict = {}      # scale-up endpoint construction kwargs
        for eng in self.runtime.engines:
            eng.on_token = self._on_token

    # ------------------------------------------------------------------
    # flight recorder (repro.obs)
    # ------------------------------------------------------------------
    @property
    def tracer(self):
        """The active tracer, or None (tracing off = zero overhead)."""
        return self.runtime.tracer

    def start_trace(self):
        """Switch the flight recorder on: create a
        :class:`~repro.obs.Tracer`, register one Perfetto track per
        engine (grouped per endpoint), and thread it through the
        runtime, every engine, and each engine's allocator. Idempotent —
        a second call returns the live tracer. Call before submitting
        work for a complete record."""
        if self.runtime.tracer is None:
            from repro.obs import Tracer
            self.runtime.tracer = Tracer()
            for ep in self.runtime.endpoints:
                self._wire_trace(ep)
        return self.runtime.tracer

    def _wire_trace(self, ep: Endpoint) -> None:
        """Register ``ep``'s engines as trace tracks. Lane naming matches
        the transfer engine's pool names (``endpoint/engine`` for pairs,
        bare ``endpoint`` for single-engine workers), so flow arrows land
        on the lanes the iteration spans live on."""
        tracer = self.runtime.tracer
        multi = len(ep.engines) > 1
        for eng in ep.engines:
            track = tracer.track(ep.name, eng.name if multi else "main")
            eng.tracer = tracer
            eng.trace_track = track
            eng.allocator.trace_engine = eng
            device = getattr(getattr(eng.device, "spec", None), "name",
                             type(eng.device).__name__)
            tracer.instant(track, "track_meta", eng.clock,
                           {"device": device,
                            "prefill_only": eng.ecfg.prefill_only,
                            "decode_only": eng.ecfg.decode_only,
                            "sched_policy": eng.ecfg.sched_policy},
                           cat="metadata")

    def export_trace(self, path: str) -> None:
        """Write the recorded trace as Perfetto-loadable Chrome JSON."""
        if self.runtime.tracer is None:
            raise ValueError("tracing was never started — call "
                             "start_trace() before the run")
        self.runtime.tracer.export(path)

    def _on_token(self, req: Request, token: int, t: float) -> None:
        # Engine.step emission hook: buffer into the request's handle for
        # its tokens() stream — but only for subscribed handles, so plain
        # batch replays retain no token history. PPI prefill views never
        # emit (prefill-only path), so each delivered token arrives here
        # exactly once.
        h = self._handles.get(req.req_id)
        if h is not None and h._streaming:
            h._stream.append((token, t))

    # ------------------------------------------------------------------
    @property
    def endpoints(self) -> List[Endpoint]:
        """Current cluster membership."""
        return self.runtime.endpoints

    @property
    def engines(self):
        """Every engine across the current membership."""
        return self.runtime.engines

    @property
    def now(self) -> float:
        """Simulated time the cluster has reached (max engine clock)."""
        return max((e.clock for e in self.runtime.engines), default=0.0)

    @property
    def n_submitted(self) -> int:
        """Requests submitted over this service's lifetime."""
        return len(self._handles)

    @property
    def n_cancelled(self) -> int:
        """Requests cancelled before completion."""
        return self._n_cancelled

    @property
    def n_finished(self) -> int:
        """Requests completed (including detached endpoints' retirees)."""
        return self.runtime.n_finished()

    @property
    def n_active(self) -> int:
        """Submitted requests still owed a completion."""
        return self.n_submitted - self._n_cancelled - self.n_finished

    @property
    def autoscaler(self):
        """The attached autoscaler, or None."""
        return self._autoscaler

    def oldest_pending_arrival(self) -> Optional[float]:
        """Arrival time of the oldest not-yet-routed submission (the
        autoscaler's view of queueing that never reached an endpoint)."""
        return self._pending[0].arrival if self._pending else None

    # ------------------------------------------------------------------
    # elastic membership (autoscaling surface)
    # ------------------------------------------------------------------
    def attach_endpoint(self, ep: Endpoint, now: Optional[float] = None
                        ) -> None:
        """Add a live endpoint mid-run (see
        :meth:`ClusterRuntime.attach_endpoint`) and wire its engines into
        this service's token-emission stream."""
        self.runtime.attach_endpoint(ep, now=now)
        for eng in ep.engines:
            eng.on_token = self._on_token
        if self.runtime.tracer is not None:
            self._wire_trace(ep)

    def detach_endpoint(self, name: str, migrate: bool = True) -> Endpoint:
        """Remove a live endpoint: its residents re-enter this service's
        pending queue (no request is lost; each re-routes on a later
        tick) and its finished requests fold into the fleet's metrics via
        ``runtime.retired``. By default residents *migrate* — their
        computed KV travels with them through the cluster
        :class:`~repro.kvcache.TransferEngine` to any endpoint that will
        ingest it, falling back to recompute only when none does — so
        scale-down never pays for re-prefilling work it already paid for.
        ``migrate=False`` forces the drain-by-recompute path."""
        return self.runtime.detach_endpoint(name, pending=self._pending,
                                            migrate=migrate)

    def attach_autoscaler(self, autoscaler) -> None:
        """Hand the scaling loop this service: ``autoscaler.on_tick`` runs
        after every ``step``. With no autoscaler attached the service
        behaves bit-identically to a fixed fleet."""
        self._autoscaler = autoscaler
        autoscaler.bind(self)

    # ------------------------------------------------------------------
    # the online surface
    # ------------------------------------------------------------------
    def submit(self, request: Request) -> RequestHandle:
        """Take ownership of a fresh request; it will be routed once
        simulated time reaches ``request.arrival``."""
        if request.req_id in self._handles:
            raise ValueError(f"duplicate req_id {request.req_id!r}")
        check_requests_fresh([request])
        # keep pending sorted by arrival, stable for ties — the dispatch
        # discipline ClusterRuntime.run's up-front sort establishes
        i = len(self._pending)
        while i > 0 and self._pending[i - 1].arrival > request.arrival:
            i -= 1
        self._pending.insert(i, request)
        handle = RequestHandle(request, self)
        self._handles[request.req_id] = handle
        tracer = self.runtime.tracer
        if tracer is not None:
            tracer.instant(tracer.control, "submit", request.arrival,
                           {"req": request.req_id,
                            "input_len": request.input_len,
                            "output_len": request.output_len})
            tracer.async_begin(tracer.control, "request", request.arrival,
                               request.req_id)
        return handle

    def cancel(self, handle: RequestHandle) -> bool:
        """Cancel a submitted request wherever it lives (pending queue
        or any endpoint); frees its slot and KV. False if already done."""
        req = handle.request
        if handle.done or handle.cancelled:
            return False
        if any(r is req for r in self._pending):      # never routed
            self._pending = deque(r for r in self._pending if r is not req)
            req.state = ReqState.CANCELLED
            req.metrics.cancelled = True
            req.metrics.cancel_time = self.now
            tracer = self.runtime.tracer
            if tracer is not None:
                tracer.instant(tracer.control, "cancel", self.now,
                               {"req": req.req_id, "pending": True})
                tracer.async_end(tracer.control, "request", self.now,
                                 req.req_id, {"cancelled": True})
        else:
            for ep in self.runtime.endpoints:
                if ep.cancel(req):
                    break
            else:
                return False
        self._n_cancelled += 1
        return True

    def step(self) -> bool:
        """One event-loop round; False when no progress is possible."""
        progressed = self.runtime.tick(self._pending)
        if self._autoscaler is not None:
            # a scaling action counts as progress: a stalled cluster that
            # just attached capacity has new work to do next round
            acted = self._autoscaler.on_tick(self)
            return progressed or acted is not None
        return progressed

    def step_until(self, t: float, max_steps: int = 10_000_000, *,
                   strict: bool = False) -> float:
        """Advance the cluster through every action due at or before
        simulated time ``t``; returns the time actually reached.
        ``strict=True`` stops short of actions due exactly at ``t`` — the
        open-loop driver uses it so a submission at ``t`` lands *before*
        the tick that executes time ``t``, matching the closed loop's
        dispatch-before-advance order within a tick. (Strict mode gates on
        ``next_action_time`` — the clock of the iteration ``tick`` will
        actually run — because ``next_time``'s delivery-only candidates
        can sit earlier than every runnable engine.)"""
        steps = 0
        while steps < max_steps:
            if strict:
                nt = self.runtime.next_action_time(self._pending)
                if nt is None or nt >= t:
                    break
            else:
                nt = self.runtime.next_time(self._pending)
                if nt is None or nt > t:
                    break
            steps += 1
            if not self.step():
                break
        return self.now

    def drain(self, max_steps: int = 10_000_000) -> Dict[str, float]:
        """Run until every non-cancelled submission finished; returns
        aggregate metrics (see :meth:`metrics`)."""
        steps = 0
        while self.n_active > 0 and steps < max_steps:
            steps += 1
            if not self.step():
                break
        return self.metrics()

    def metrics(self, ttft_slo: Optional[float] = None,
                tbt_slo: Optional[float] = None,
                queueing: bool = False,
                utilization: bool = False) -> Dict[str, float]:
        """Fleet QoE aggregate over everything terminal so far. Finished
        requests feed throughput/latency; cancelled ones only the
        ``cancelled`` count (they never enter throughput aggregates).
        ``queueing=True`` (the open-loop driver's view) adds the
        queueing/service split of TTFT. ``utilization=True`` adds a
        per-endpoint breakdown (trailing-window ``busy_frac``, max queued
        age, router ``dispatched`` count, ``completed`` count) under one
        ``"utilization"`` key — how planner probes attribute a miss to
        the endpoint that caused it. Both opt-in: the default dict stays
        byte-identical."""
        ms = [r.metrics for ep in self.runtime.endpoints
              for r in ep.finished()]
        ms += [r.metrics for r in self.runtime.retired]
        ms += [h.request.metrics for h in self._handles.values()
               if h.request.metrics.cancelled]
        util = None
        if utilization:
            util = {}
            for ep in self.runtime.endpoints:
                s = ep.stats()
                util[ep.name] = {
                    "busy_frac": s.busy_frac,
                    "oldest_queued_age": s.oldest_queued_age,
                    "dispatched": self.runtime.dispatched.get(ep.name, 0),
                    "completed": ep.n_finished(),
                }
            # cluster-wide KV movement (per-kind token counters +
            # cancellation stats) — only when transfers actually ran, so
            # transfer-free topologies keep their exact utilization dict
            if self.runtime.transfers.n_transfers > 0:
                util["transfers"] = self.runtime.transfers.stats()
        return aggregate(ms, ttft_slo, tbt_slo, queueing=queueing,
                         utilization=util)

    # ------------------------------------------------------------------
    # the legacy batch surface
    # ------------------------------------------------------------------
    def run(self, requests: List[Request],
            max_steps: int = 10_000_000) -> Dict[str, float]:
        """Replay a whole trace: submit-all + drain. Metrics are
        bit-identical to the legacy ``system.run(trace)`` of the
        underlying builders."""
        for r in requests:
            self.submit(r)
        return self.drain(max_steps)


def serve(spec: ServeSpec, **replacements) -> InferenceService:
    """Convenience one-liner: ``serve(spec, sched_policy="sarathi")``."""
    if replacements:
        spec = spec.replace(**replacements)
    return spec.build()
