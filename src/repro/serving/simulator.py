"""Paper-scale serving experiments: 5 approaches x (hardware, model) grid.

The same scheduler/balancer/engine code as the functional path, driven by
``NullExecutor`` (no tensor compute) and the roofline device-time models —
i.e., a discrete-event simulation whose *control flow* is the production
code. Reproduces the shape of Table 2 (max throughput), Fig. 4 (TTFT/TBT
P99) and Table 3 (disaggregated load imbalance).
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.balancer import Balancer
from repro.core.baselines import build_dp, build_pp
from repro.core.cronus import build_cronus, build_disaggregated
from repro.core.executor import NullExecutor
from repro.core.predictor import profile_chunked, profile_prefill
from repro.core.request import Request
from repro.serving.hardware import DeviceModel, DeviceSpec
from repro.serving.trace import Trace

APPROACHES = ("cronus", "dp", "pp", "disagg_hl", "disagg_lh")


def _null_factory(role: str):
    return NullExecutor()


def build_system(approach: str, cfg, hi_spec: DeviceSpec, lo_spec: DeviceSpec,
                 *, max_slots: int = 256, block_size: int = 16,
                 max_batched_tokens: int = 512, executor_factory=None,
                 sched_policy: str = "fcfs", prefix_cache: bool = False,
                 num_kv_blocks=None, host_kv_blocks: int = 0,
                 executor: str = "null"):
    """Build one of the five approaches as a runnable system facade."""
    executor_factory = executor_factory or _null_factory
    hi = DeviceModel(hi_spec, cfg)
    lo = DeviceModel(lo_spec, cfg)
    kw = dict(executor_factory=executor_factory, max_slots=max_slots,
              block_size=block_size, sched_policy=sched_policy,
              prefix_cache=prefix_cache, num_kv_blocks=num_kv_blocks,
              host_kv_blocks=host_kv_blocks, executor=executor)
    if approach == "cronus":
        bal = Balancer(profile_prefill(lo), profile_chunked(hi))
        return build_cronus(cfg, lo, hi, balancer=bal,
                            max_batched_tokens=max_batched_tokens, **kw)
    if approach == "disagg_lh":   # prefill on low-end, decode on high-end
        return build_disaggregated(cfg, lo, hi,
                                   max_batched_tokens=max_batched_tokens, **kw)
    if approach == "disagg_hl":   # prefill on high-end, decode on low-end
        return build_disaggregated(cfg, hi, lo,
                                   max_batched_tokens=max_batched_tokens, **kw)
    if approach == "dp":
        return build_dp(cfg, hi, lo, **kw)
    if approach == "pp":
        return build_pp(cfg, hi_spec, lo_spec, **kw)
    raise KeyError(approach)


def run_approach(approach: str, cfg, hi_spec, lo_spec,
                 requests: List[Request], **kw) -> Dict[str, float]:
    """Build an approach, replay a trace, return aggregate metrics."""
    system = build_system(approach, cfg, hi_spec, lo_spec, **kw)
    return system.run(Trace(requests).fresh())


def compare_all(cfg, hi_spec, lo_spec, requests,
                approaches=APPROACHES, **kw) -> Dict[str, Dict[str, float]]:
    """Metrics for every approach on the same (fresh) trace."""
    return {a: run_approach(a, cfg, hi_spec, lo_spec, requests, **kw)
            for a in approaches}


# ---------------------------------------------------------------------------
# Table 3: relative utilization of the disaggregated configurations
# ---------------------------------------------------------------------------

def max_prefill_throughput(device: DeviceModel, requests) -> float:
    """Requests/s if the instance did nothing but full prefills."""
    total = sum(device.prefill_time(r.input_len) for r in requests)
    return len(requests) / total


def max_decode_throughput(device: DeviceModel, requests, *,
                          max_slots: int = 256, block_size: int = 16) -> float:
    """Requests/s if the instance did nothing but decode (prompts appear
    pre-filled): bounded by memory (batch) and decode iteration time."""
    budget_tokens = device.kv_block_budget(block_size) * block_size
    avg_ctx = sum(r.input_len + r.output_len / 2 for r in requests) / len(requests)
    avg_out = sum(r.output_len for r in requests) / len(requests)
    batch = max(min(max_slots, int(budget_tokens / max(avg_ctx, 1))), 1)
    t_iter = device.decode_iter_time(batch * avg_ctx, batch)
    # one iteration decodes `batch` tokens; a request needs avg_out tokens
    return batch / (avg_out * t_iter)


def utilization_table(cfg, hi_spec, lo_spec, requests) -> Dict[str, Dict[str, float]]:
    """Paper Table 3: system throughput / standalone instance throughput."""
    hi, lo = DeviceModel(hi_spec, cfg), DeviceModel(lo_spec, cfg)
    out = {}
    for name, pre_dev, dec_dev in (("disagg_hl", hi, lo), ("disagg_lh", lo, hi)):
        res = run_approach(name, cfg, hi_spec, lo_spec, requests)
        tput = res["throughput"]
        out[name] = {
            "prefill_util": tput / max_prefill_throughput(pre_dev, requests),
            "decode_util": tput / max_decode_throughput(dec_dev, requests),
            "throughput": tput,
        }
    return out
