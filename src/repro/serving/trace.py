"""Azure-LLM-inference-style conversation traces (paper §5.1).

The paper replays 1000 conversation traces from Microsoft's Azure LLM
inference trace 2023 (mean input 1014, mean output 247 tokens), sent at a
fixed interval (latency runs) or all at t=0 (max-throughput runs). The trace
file is not redistributable, so we generate statistically matched synthetic
traces: log-normal lengths calibrated to the published means, deterministic
per seed.
"""
from __future__ import annotations

import copy
from typing import List, Optional

import numpy as np

from repro.core.request import Request

AZURE_CONV_MEAN_IN = 1014
AZURE_CONV_MEAN_OUT = 247


class Trace(List[Request]):
    """A replayable request list.

    Engines mutate requests in place (state, generated tokens, shared
    metrics objects), so handing the same ``Request`` objects to a second
    system silently corrupts its run — the classic aliasing footgun this
    class closes: ``ClusterRuntime.run`` refuses already-replayed
    requests, and ``fresh()`` hands out deep copies so one trace can
    drive any number of systems::

        trace = make_trace(1000)
        a = system_a.run(trace.fresh())
        b = system_b.run(trace.fresh())
    """

    def fresh(self) -> "Trace":
        """Deep copies of every request, safe to replay."""
        return Trace(copy.deepcopy(r) for r in self)


def synth_lengths(n: int, mean: float, sigma: float, rng, lo: int, hi: int):
    mu = np.log(mean) - sigma ** 2 / 2.0    # log-normal with E[X]=mean
    return np.clip(rng.lognormal(mu, sigma, n).astype(int), lo, hi)


def make_trace(n_requests: int = 1000, *, seed: int = 0,
               interval: float = 0.0,
               mean_in: float = AZURE_CONV_MEAN_IN,
               mean_out: float = AZURE_CONV_MEAN_OUT,
               max_in: int = 8192, max_out: int = 1024,
               vocab_size: int = 32000,
               scale: float = 1.0,
               sessions: Optional[int] = None) -> Trace:
    """interval=0 -> all requests at t=0 (max-throughput measurement).
    ``scale`` shrinks lengths for CPU-scale functional runs.
    ``sessions`` tags requests with conversation ids drawn from that many
    sessions (round-robin), for session-affinity routing experiments."""
    rng = np.random.default_rng(seed)
    ins = synth_lengths(n_requests, mean_in * scale, 1.0, rng,
                        max(int(4 * scale), 2), int(max_in * scale))
    outs = synth_lengths(n_requests, mean_out * scale, 0.6, rng,
                         max(int(2 * scale), 1), int(max_out * scale))
    reqs = Trace()
    for i in range(n_requests):
        prompt = rng.integers(0, vocab_size, ins[i]).astype(np.int32)
        reqs.append(Request(req_id=f"r{i}", prompt=prompt,
                            output_len=int(outs[i]),
                            arrival=i * interval,
                            session=(f"s{i % sessions}" if sessions
                                     else None)))
    return reqs


def make_shared_prefix_trace(n_requests: int = 1000, *, seed: int = 0,
                             interval: float = 0.0,
                             n_prefixes: int = 8,
                             prefix_len: int = 512,
                             mean_suffix_in: float = 256,
                             mean_out: float = AZURE_CONV_MEAN_OUT,
                             max_in: int = 4096, max_out: int = 1024,
                             vocab_size: int = 32000,
                             scale: float = 1.0) -> Trace:
    """Multi-tenant shared-prefix workload: each request opens with one of
    ``n_prefixes`` common prefixes (system prompt / few-shot template) of
    ``prefix_len`` tokens, followed by a log-normal unique suffix. The
    prefix id doubles as the session tag, so session- and prefix-affinity
    routers can chase KV locality. This is the workload where block-level
    prefix caching pays: without it every request re-prefills its
    template."""
    rng = np.random.default_rng(seed)
    p_len = max(int(prefix_len * scale), 2)
    prefixes = [rng.integers(0, vocab_size, p_len).astype(np.int32)
                for _ in range(n_prefixes)]
    sfx = synth_lengths(n_requests, mean_suffix_in * scale, 1.0, rng,
                        max(int(4 * scale), 2), int(max_in * scale))
    outs = synth_lengths(n_requests, mean_out * scale, 0.6, rng,
                         max(int(2 * scale), 1), int(max_out * scale))
    groups = rng.integers(0, n_prefixes, n_requests)
    reqs = Trace()
    for i in range(n_requests):
        g = int(groups[i])
        suffix = rng.integers(0, vocab_size, sfx[i]).astype(np.int32)
        reqs.append(Request(req_id=f"r{i}",
                            prompt=np.concatenate([prefixes[g], suffix]),
                            output_len=int(outs[i]),
                            arrival=i * interval,
                            session=f"p{g}"))
    return reqs
