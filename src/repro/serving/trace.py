"""Azure-LLM-inference-style conversation traces (paper §5.1).

The paper replays 1000 conversation traces from Microsoft's Azure LLM
inference trace 2023 (mean input 1014, mean output 247 tokens), sent at a
fixed interval (latency runs) or all at t=0 (max-throughput runs). The trace
file is not redistributable, so we generate statistically matched synthetic
traces: log-normal lengths calibrated to the published means, deterministic
per seed.

Arrival assignment is delegated to :mod:`repro.workloads.arrivals`: pass
``arrival="poisson:6"`` (or any :class:`~repro.workloads.arrivals.
ArrivalProcess`) for open-loop traffic models; the ``interval=`` keyword
survives as a back-compat alias for ``fixed:INTERVAL``. Lengths and
arrivals draw from independent rng streams, so the same seed yields the
same request bodies under every arrival model.
"""
from __future__ import annotations

import copy
from typing import List, Optional, Union

import numpy as np

from repro.core.request import Request
from repro.workloads.arrivals import ArrivalProcess, FixedInterval, \
    parse_arrival

AZURE_CONV_MEAN_IN = 1014
AZURE_CONV_MEAN_OUT = 247

# arrivals draw from their own seed stream ("ARRV") so the length samplers
# below consume exactly the seed's draws regardless of the arrival model
_ARRIVAL_STREAM = 0x41525256

ArrivalLike = Union[ArrivalProcess, str, None]


def _resolve_arrival(arrival: ArrivalLike, interval: float) -> ArrivalProcess:
    if arrival is None:
        return FixedInterval(interval)
    if interval:
        raise ValueError("pass either interval= (back-compat fixed spacing) "
                         "or arrival=, not both")
    return parse_arrival(arrival)


def _arrival_times(arrival: ArrivalLike, interval: float, n: int,
                   seed: int) -> np.ndarray:
    proc = _resolve_arrival(arrival, interval)
    return proc.times(n, np.random.default_rng([_ARRIVAL_STREAM, seed]))


class Trace(List[Request]):
    """A replayable request list.

    Engines mutate requests in place (state, generated tokens, shared
    metrics objects), so handing the same ``Request`` objects to a second
    system silently corrupts its run — the classic aliasing footgun this
    class closes: ``ClusterRuntime.run`` refuses already-replayed
    requests, and ``fresh()`` hands out deep copies so one trace can
    drive any number of systems::

        trace = make_trace(1000)
        a = system_a.run(trace.fresh())
        b = system_b.run(trace.fresh())
    """

    def fresh(self) -> "Trace":
        """Deep copies of every request, safe to replay."""
        return Trace(copy.deepcopy(r) for r in self)


def synth_lengths(n: int, mean: float, sigma: float, rng, lo: int, hi: int):
    """Clipped log-normal lengths with mean ``mean``."""
    mu = np.log(mean) - sigma ** 2 / 2.0    # log-normal with E[X]=mean
    return np.clip(rng.lognormal(mu, sigma, n).astype(int), lo, hi)


def sample_lengths(rng, n: int, *, mean_in: float, mean_out: float,
                   max_in: int, max_out: int, scale: float = 1.0):
    """The shared (input, output) length sampler both trace generators
    draw from: log-normal with σ=1.0 on inputs and σ=0.6 on outputs,
    calibrated so E[in]=mean_in / E[out]=mean_out, clipped to the device-
    survivable range. ``scale`` shrinks everything proportionally for
    CPU-scale functional runs. Consumes exactly two draws from ``rng``
    (inputs first), byte-identical to the seed's inline sampling."""
    ins = synth_lengths(n, mean_in * scale, 1.0, rng,
                        max(int(4 * scale), 2), int(max_in * scale))
    outs = synth_lengths(n, mean_out * scale, 0.6, rng,
                         max(int(2 * scale), 1), int(max_out * scale))
    return ins, outs


def make_trace(n_requests: int = 1000, *, seed: int = 0,
               interval: float = 0.0,
               arrival: ArrivalLike = None,
               mean_in: float = AZURE_CONV_MEAN_IN,
               mean_out: float = AZURE_CONV_MEAN_OUT,
               max_in: int = 8192, max_out: int = 1024,
               vocab_size: int = 32000,
               scale: float = 1.0,
               sessions: Optional[int] = None) -> Trace:
    """``arrival`` names the traffic model (an ``ArrivalProcess`` or a
    spec string such as ``"poisson:6"``); ``interval=I`` is the
    back-compat alias for ``fixed:I`` (0 -> all requests at t=0, the
    max-throughput measurement). ``scale`` shrinks lengths for CPU-scale
    functional runs. ``sessions`` tags requests with conversation ids
    drawn from that many sessions (round-robin), for session-affinity
    routing experiments."""
    rng = np.random.default_rng(seed)
    ins, outs = sample_lengths(rng, n_requests, mean_in=mean_in,
                               mean_out=mean_out, max_in=max_in,
                               max_out=max_out, scale=scale)
    arrivals = _arrival_times(arrival, interval, n_requests, seed)
    reqs = Trace()
    for i in range(n_requests):
        prompt = rng.integers(0, vocab_size, ins[i]).astype(np.int32)
        reqs.append(Request(req_id=f"r{i}", prompt=prompt,
                            output_len=int(outs[i]),
                            arrival=float(arrivals[i]),
                            session=(f"s{i % sessions}" if sessions
                                     else None)))
    return reqs


def make_shared_prefix_trace(n_requests: int = 1000, *, seed: int = 0,
                             interval: float = 0.0,
                             arrival: ArrivalLike = None,
                             n_prefixes: int = 8,
                             prefix_len: int = 512,
                             mean_suffix_in: float = 256,
                             mean_out: float = AZURE_CONV_MEAN_OUT,
                             max_in: int = 4096, max_out: int = 1024,
                             vocab_size: int = 32000,
                             scale: float = 1.0) -> Trace:
    """Multi-tenant shared-prefix workload: each request opens with one of
    ``n_prefixes`` common prefixes (system prompt / few-shot template) of
    ``prefix_len`` tokens, followed by a log-normal unique suffix. The
    prefix id doubles as the session tag, so session- and prefix-affinity
    routers can chase KV locality. This is the workload where block-level
    prefix caching pays: without it every request re-prefills its
    template."""
    rng = np.random.default_rng(seed)
    p_len = max(int(prefix_len * scale), 2)
    prefixes = [rng.integers(0, vocab_size, p_len).astype(np.int32)
                for _ in range(n_prefixes)]
    sfx, outs = sample_lengths(rng, n_requests, mean_in=mean_suffix_in,
                               mean_out=mean_out, max_in=max_in,
                               max_out=max_out, scale=scale)
    groups = rng.integers(0, n_prefixes, n_requests)
    arrivals = _arrival_times(arrival, interval, n_requests, seed)
    reqs = Trace()
    for i in range(n_requests):
        g = int(groups[i])
        suffix = rng.integers(0, vocab_size, sfx[i]).astype(np.int32)
        reqs.append(Request(req_id=f"r{i}",
                            prompt=np.concatenate([prefixes[g], suffix]),
                            output_len=int(outs[i]),
                            arrival=float(arrivals[i]),
                            session=f"p{g}"))
    return reqs
