"""Device specs + roofline iteration-time model for heterogeneous serving.

The paper profiles real GPUs and fits linear predictors (§4.4). Without GPUs
in this container, iteration times come from a roofline cost model over
published device specs — the same linearity in (prefill context, decode
context) emerges, so the paper's regression machinery fits these times with
R² comparable to the paper's (validated in bench_fig3_predictor_fit).

TPU entries map the paper's heterogeneity onto pods of different
generations (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """Published capability numbers for one accelerator type."""

    name: str
    flops: float          # peak bf16 FLOP/s
    hbm_bw: float         # bytes/s
    hbm_cap: float        # bytes
    link_bw: float        # bytes/s to the peer device (IB / ICI / DCN)
    flops_eff: float = 0.55   # achievable fraction of peak in mixed batches
    bw_eff: float = 0.75
    overhead: float = 3.0e-3  # fixed per-iteration launch/schedule overhead (s)
    # device <-> host-DRAM bandwidth (PCIe 4.0 x16 ~ 32 GB/s for the GPUs;
    # TPU hosts see similar PCIe attach) — the cost the host-memory KV tier
    # pays on demote/promote
    pcie_bw: float = 32e9


# published specs; link = IB 100 Gb/s for GPUs, ICI/DCN for TPUs
A100 = DeviceSpec("A100", 312e12, 2039e9, 80e9, 12.5e9)
A30 = DeviceSpec("A30", 165e12, 933e9, 24e9, 12.5e9)
A10 = DeviceSpec("A10", 125e12, 600e9, 24e9, 12.5e9)
V5E = DeviceSpec("TPUv5e", 197e12, 819e9, 16e9, 50e9)
V4 = DeviceSpec("TPUv4", 275e12, 1228e9, 32e9, 50e9)

DEVICES = {d.name: d for d in (A100, A30, A10, V5E, V4)}


# ---------------------------------------------------------------------------
# per-model cost primitives
# ---------------------------------------------------------------------------

def kv_bytes_per_token(cfg: ModelConfig) -> float:
    """KV-cache bytes appended per token (bf16)."""
    if cfg.arch_type == "ssm":
        return 0.0  # constant state, not per-token
    if cfg.mla_kv_lora_rank:
        per_layer = cfg.mla_kv_lora_rank + cfg.mla_rope_head_dim
    else:
        per_layer = 2 * cfg.n_kv_heads * cfg.head_dim
    return 2.0 * cfg.n_layers * per_layer


def ssm_state_bytes(cfg: ModelConfig) -> float:
    """Recurrent-state bytes per request (fp32 state + conv cache)."""
    if not cfg.ssm_state:
        return 0.0
    d_inner = cfg.ssm_expand * cfg.d_model
    n_h = cfg.ssm_n_heads or max(1, d_inner // cfg.ssm_head_dim)
    p = d_inner // n_h
    state = 4.0 * n_h * p * cfg.ssm_state
    conv = 2.0 * (cfg.ssm_conv_width - 1) * (d_inner + 2 * cfg.ssm_state)
    return cfg.n_layers * (state + conv)


def transfer_bytes(cfg: ModelConfig, n_tokens: int) -> float:
    """Bytes shipped PPI->CPI for a partial prefill of n_tokens."""
    return kv_bytes_per_token(cfg) * n_tokens + ssm_state_bytes(cfg)


def param_bytes(cfg: ModelConfig) -> float:
    """Weight bytes at bf16."""
    return 2.0 * cfg.param_count()


def active_param_bytes(cfg: ModelConfig) -> float:
    """Bytes of weights touched per token (MoE: active experts only)."""
    return 2.0 * cfg.active_param_count()


def matmul_flops_per_token(cfg: ModelConfig) -> float:
    """Dense matmul FLOPs per token (2 * active params)."""
    return 2.0 * cfg.active_param_count()


def attn_flops(cfg: ModelConfig, new_tokens: float, avg_ctx: float) -> float:
    """score + value matmuls over context (per full forward of new_tokens)."""
    if cfg.arch_type == "ssm":
        # SSD intra-chunk matmuls ~ O(tokens * chunk * (N + P))
        d_inner = cfg.ssm_expand * cfg.d_model
        return 4.0 * cfg.n_layers * new_tokens * cfg.ssm_chunk * (
            cfg.ssm_state + d_inner / max(cfg.ssm_n_heads, 1))
    hd = cfg.head_dim if not cfg.mla_kv_lora_rank else (
        cfg.mla_nope_head_dim + cfg.mla_rope_head_dim)
    return 4.0 * cfg.n_layers * cfg.n_heads * hd * new_tokens * avg_ctx


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    """Roofline iteration-time model for one model on one device."""
    spec: DeviceSpec
    cfg: ModelConfig

    def _time(self, flops: float, bytes_: float) -> float:
        t_c = flops / (self.spec.flops * self.spec.flops_eff)
        t_m = bytes_ / (self.spec.hbm_bw * self.spec.bw_eff)
        return max(t_c, t_m) + self.spec.overhead

    def prefill_time(self, n_tokens: int, ctx_start: int = 0) -> float:
        """Full/partial prefill of n_tokens starting from ctx_start."""
        avg_ctx = ctx_start + n_tokens / 2.0
        f = matmul_flops_per_token(self.cfg) * n_tokens \
            + attn_flops(self.cfg, n_tokens, avg_ctx)
        by = active_param_bytes(self.cfg) \
            + kv_bytes_per_token(self.cfg) * (ctx_start + n_tokens)
        return self._time(f, by)

    def chunked_iter_time(self, prefill_tokens: int, prefill_ctx: int,
                          decode_ctx_sum: float, n_decode: int) -> float:
        """One CPI iteration: a prefill chunk + piggybacked decodes (Eq. 3's
        ground truth)."""
        new = prefill_tokens + n_decode
        f = matmul_flops_per_token(self.cfg) * new \
            + attn_flops(self.cfg, prefill_tokens,
                         prefill_ctx + prefill_tokens / 2.0) \
            + attn_flops(self.cfg, 1, decode_ctx_sum)
        by = active_param_bytes(self.cfg) \
            + kv_bytes_per_token(self.cfg) * (
                prefill_ctx + prefill_tokens + decode_ctx_sum + new)
        return self._time(f, by)

    def decode_iter_time(self, decode_ctx_sum: float, n_decode: int) -> float:
        """Seconds for one decode-only iteration."""
        return self.chunked_iter_time(0, 0, decode_ctx_sum, n_decode)

    def transfer_time(self, n_tokens: int) -> float:
        """Seconds to ship n_tokens of KV across the inter-device link."""
        return transfer_bytes(self.cfg, n_tokens) / self.spec.link_bw

    def host_kv_time(self, n_tokens: int) -> float:
        """Seconds to move n_tokens of KV across PCIe (host-memory tier
        demotions/promotions — charged by the engine, overlapped with
        compute like link transfers)."""
        return transfer_bytes(self.cfg, n_tokens) / self.spec.pcie_bw

    # capacity: how many KV blocks fit beside the weights
    def kv_block_budget(self, block_size: int, mem_frac: float = 0.9) -> int:
        """KV blocks that fit in HBM beside the weights."""
        free = self.spec.hbm_cap * mem_frac - param_bytes(self.cfg)
        per_block = kv_bytes_per_token(self.cfg) * block_size
        if per_block <= 0:
            return 1_000_000  # SSM: constant state, effectively unbounded
        return max(int(free / per_block), 0)
