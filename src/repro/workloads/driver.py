"""Live-submission open-loop driver.

``ClusterRuntime.run(trace)`` is a *closed-loop* replay: the whole trace
is pending from step one, so routers and balancers see the future and
queueing never builds up the way it does when requests actually arrive
over time. :class:`OpenLoopDriver` replays the same workload *honestly*:
each request is handed to :meth:`InferenceService.submit` only once
simulated time reaches its arrival, with
:meth:`InferenceService.step_until` advancing the cluster through
everything due strictly before that instant. The request stream is
consumed in order — never pre-sorted, never materialised ahead of the
clock — so the service learns about a request exactly when an online
system would.

Fixed-interval arrivals are the degenerate case: the driver then
reproduces the closed-loop ``run(trace)`` aggregate metrics exactly
(``tests/test_workloads.py`` asserts dict equality), because engine
admission always gated on each request's ``arrival`` anyway — the closed
loop's foreknowledge only ever mattered to cross-request *policy* probes
(load-dependent balancing/routing), which fixed spacing leaves on the
same schedule.

On top of the usual TTFT/TBT aggregates the driver separates *queueing*
from *service*: every request records ``service_start_time`` when it
first wins a KV slot on any engine, and :meth:`OpenLoopDriver.metrics`
opts into the ``queueing_p50`` / ``queueing_p99`` / ``ttft_service_p99``
aggregate keys (closed-loop replays never emit them, keeping their
metric dicts byte-identical to the seed's).
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, Iterable, List, Optional

from repro.core.request import Request

if TYPE_CHECKING:                  # driver is duck-typed over the service
    from repro.serving.api import InferenceService, RequestHandle


class OpenLoopDriver:
    """Submit a request stream at its wall-time offsets over a built
    :class:`~repro.serving.api.InferenceService`.

    Example::

        service = ServeSpec(approach="cronus").build()
        trace = make_trace(1000, arrival="poisson:6")
        driver = OpenLoopDriver(service)
        driver.run(trace)                  # live submission + drain
        curve_point = driver.metrics(ttft_slo=5.0, tbt_slo=0.2)
    """

    def __init__(self, service: "InferenceService"):
        self.service = service
        self.handles: List["RequestHandle"] = []

    def run(self, requests: Iterable[Request],
            max_steps: int = 10_000_000) -> Dict[str, float]:
        """Drive the stream to completion; returns the same aggregate
        dict ``InferenceService.drain`` produces (use :meth:`metrics`
        for the queueing-separated view).

        ``requests`` must already be in arrival order — arrival
        processes generate monotone timestamps, and sorting here would
        quietly re-introduce the closed loop's future knowledge — so
        out-of-order input is refused loudly instead.
        """
        last: Optional[float] = None
        for req in requests:
            if last is not None and req.arrival < last:
                raise ValueError(
                    f"open-loop submission needs arrival-ordered requests: "
                    f"{req.req_id!r} arrives at {req.arrival:.6f} after one "
                    f"at {last:.6f} (the driver never pre-sorts — sort the "
                    "stream at generation time)")
            last = req.arrival
            # advance through everything due strictly BEFORE this arrival,
            # then submit: a tick at exactly t=arrival runs with the
            # request already pending, matching the closed loop's
            # dispatch-before-advance order within a tick
            self.service.step_until(req.arrival, strict=True)
            self.handles.append(self.service.submit(req))
        return self.service.drain(max_steps)

    def metrics(self, ttft_slo: Optional[float] = None,
                tbt_slo: Optional[float] = None,
                utilization: bool = False) -> Dict[str, float]:
        """Aggregate metrics with the open-loop-only queueing keys
        (``queueing_p50`` / ``queueing_p99`` / ``ttft_service_p99``) and,
        when both SLOs are given, ``goodput``. ``utilization=True``
        passes through the per-endpoint breakdown."""
        return self.service.metrics(ttft_slo, tbt_slo, queueing=True,
                                    utilization=utilization)

    @property
    def n_submitted(self) -> int:
        return len(self.handles)
