"""Open-loop workload subsystem: arrival processes, a live-submission
driver over :class:`~repro.serving.api.InferenceService`, and the rate
sweep / SLO capacity search built on them. See the module docstrings of
:mod:`repro.workloads.arrivals`, :mod:`repro.workloads.driver` and
:mod:`repro.workloads.sweep`."""
from repro.workloads.arrivals import (ARRIVAL_KINDS, ArrivalProcess,
                                      BurstyProcess, DiurnalRamp,
                                      FixedInterval, PoissonProcess,
                                      parse_arrival)
from repro.workloads.driver import OpenLoopDriver
from repro.workloads.sweep import (DEFAULT_TBT_SLO, DEFAULT_TTFT_SLO,
                                   CapacityResult, capacity_search,
                                   find_capacity, open_loop_measure,
                                   rate_sweep)

__all__ = [
    "ARRIVAL_KINDS", "ArrivalProcess", "BurstyProcess", "DiurnalRamp",
    "FixedInterval", "PoissonProcess", "parse_arrival",
    "OpenLoopDriver",
    "DEFAULT_TBT_SLO", "DEFAULT_TTFT_SLO", "CapacityResult",
    "capacity_search", "find_capacity", "open_loop_measure", "rate_sweep",
]
