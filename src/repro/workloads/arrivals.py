"""Arrival processes for open-loop workload generation.

The paper's headline numbers are *tail* claims — TTFT P99 / TBT P99 under
a controlled request rate (§5, Fig. 4) — and tails only form when arrivals
are allowed to queue the way real traffic does. An :class:`ArrivalProcess`
turns "n requests" into "n arrival timestamps" under a named traffic
model, deterministically per seed, and composes with the log-normal
length samplers in :mod:`repro.serving.trace` (lengths and arrivals draw
from independent rng streams, so switching the arrival model never
changes the request bodies).

Four models cover the evaluation space:

  * :class:`FixedInterval` — the seed's ``i * interval`` assignment
    (``interval=0`` = everything at t0, the max-throughput degenerate
    case). Consumes no randomness, so traces built through it are
    byte-identical to the historical ``interval=`` path.
  * :class:`PoissonProcess` — memoryless open-loop load at a target QPS,
    the paper's rate-swept setting.
  * :class:`BurstyProcess` — Markov-modulated on/off Poisson: ON phases
    at ``burstiness`` times the long-run rate alternate with silent OFF
    phases, exposing schedulers to queue build-up that a smooth Poisson
    stream of the same average rate never produces.
  * :class:`DiurnalRamp` — sinusoidal rate between ``rate_lo`` and
    ``rate_hi`` (thinning construction), a slow load swing for
    autoscaling experiments.

String specs (CLI / ``ServeSpec.arrival``) round-trip through
:func:`parse_arrival`::

    fixed:INTERVAL
    poisson:RATE
    burst:RATE[:BURSTINESS[:MEAN_ON]]
    ramp:RATE_LO:RATE_HI[:PERIOD]
"""
from __future__ import annotations

import abc
import dataclasses
import math
from typing import Union

import numpy as np

RngLike = Union[int, np.random.Generator]


def _as_rng(rng: RngLike) -> np.random.Generator:
    if isinstance(rng, np.random.Generator):
        return rng
    return np.random.default_rng(rng)


class ArrivalProcess(abc.ABC):
    """A traffic model: ``times(n, rng)`` -> n non-decreasing arrival
    timestamps (seconds, starting near 0). Deterministic for a given
    seed/generator state."""

    kind: str = "?"

    @abc.abstractmethod
    def times(self, n: int, rng: RngLike = 0) -> np.ndarray:
        """n sorted arrival times >= 0 as float64."""

    @property
    @abc.abstractmethod
    def spec(self) -> str:
        """Round-trippable string form (``parse_arrival(p.spec) == p``)."""

    @property
    @abc.abstractmethod
    def mean_rate(self) -> float:
        """Long-run average arrival rate (req/s); ``inf`` for fixed:0."""

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.spec!r})"


@dataclasses.dataclass(frozen=True, repr=False)
class FixedInterval(ArrivalProcess):
    """``i * interval`` — the seed's deterministic spacing. ``interval=0``
    puts every request at t0 (max-throughput closed-loop replay)."""

    interval: float = 0.0
    kind = "fixed"

    def __post_init__(self):
        if self.interval < 0:
            raise ValueError("fixed arrival needs interval >= 0, "
                             f"got {self.interval}")

    def times(self, n: int, rng: RngLike = 0) -> np.ndarray:
        # consumes no randomness: traces built through FixedInterval are
        # byte-identical to the historical `arrival = i * interval`
        return np.arange(n, dtype=np.float64) * self.interval

    @property
    def spec(self) -> str:
        return f"fixed:{self.interval!r}"

    @property
    def mean_rate(self) -> float:
        return 1.0 / self.interval if self.interval > 0 else math.inf


@dataclasses.dataclass(frozen=True, repr=False)
class PoissonProcess(ArrivalProcess):
    """Memoryless arrivals at ``rate`` req/s (exponential interarrivals)."""

    rate: float
    kind = "poisson"

    def __post_init__(self):
        if self.rate <= 0:
            raise ValueError(f"poisson arrival needs rate > 0, got {self.rate}")

    def times(self, n: int, rng: RngLike = 0) -> np.ndarray:
        rng = _as_rng(rng)
        return np.cumsum(rng.exponential(1.0 / self.rate, n))

    @property
    def spec(self) -> str:
        return f"poisson:{self.rate!r}"

    @property
    def mean_rate(self) -> float:
        return self.rate


@dataclasses.dataclass(frozen=True, repr=False)
class BurstyProcess(ArrivalProcess):
    """Markov-modulated on/off Poisson: exponential ON phases (mean
    ``mean_on`` s) fire at ``rate * burstiness``; exponential OFF phases
    (mean ``mean_on * (burstiness - 1)``) are silent, so the long-run
    average is exactly ``rate`` while the instantaneous load the
    scheduler faces is ``burstiness`` times higher."""

    rate: float
    burstiness: float = 4.0
    mean_on: float = 5.0
    kind = "burst"

    def __post_init__(self):
        if self.rate <= 0:
            raise ValueError(f"burst arrival needs rate > 0, got {self.rate}")
        if self.burstiness < 1:
            raise ValueError("burst arrival needs burstiness >= 1 "
                             f"(peak-to-mean ratio), got {self.burstiness}")
        if self.mean_on <= 0:
            raise ValueError(f"burst arrival needs mean_on > 0, "
                             f"got {self.mean_on}")

    def times(self, n: int, rng: RngLike = 0) -> np.ndarray:
        rng = _as_rng(rng)
        if self.burstiness == 1.0:           # degenerate: plain Poisson
            return np.cumsum(rng.exponential(1.0 / self.rate, n))
        rate_on = self.rate * self.burstiness
        mean_off = self.mean_on * (self.burstiness - 1.0)
        out = np.empty(n, dtype=np.float64)
        i, t = 0, 0.0
        while i < n:
            on_end = t + rng.exponential(self.mean_on)
            while i < n:
                t += rng.exponential(1.0 / rate_on)
                if t >= on_end:
                    break                     # overshoot discarded (memoryless)
                out[i] = t
                i += 1
            t = on_end + rng.exponential(mean_off)
        return out

    @property
    def spec(self) -> str:
        return (f"burst:{self.rate!r}:{self.burstiness!r}"
                f":{self.mean_on!r}")

    @property
    def mean_rate(self) -> float:
        return self.rate


@dataclasses.dataclass(frozen=True, repr=False)
class DiurnalRamp(ArrivalProcess):
    """Sinusoidal rate swing between ``rate_lo`` and ``rate_hi`` with
    period ``period`` seconds (starts at the trough), generated by
    thinning a ``rate_hi`` Poisson majorant."""

    rate_lo: float
    rate_hi: float
    period: float = 60.0
    kind = "ramp"

    def __post_init__(self):
        if self.rate_lo <= 0 or self.rate_hi < self.rate_lo:
            raise ValueError("ramp arrival needs 0 < rate_lo <= rate_hi, "
                             f"got {self.rate_lo}..{self.rate_hi}")
        if self.period <= 0:
            raise ValueError(f"ramp arrival needs period > 0, "
                             f"got {self.period}")

    def rate_at(self, t: float) -> float:
        phase = 0.5 * (1.0 - math.cos(2.0 * math.pi * t / self.period))
        return self.rate_lo + (self.rate_hi - self.rate_lo) * phase

    def times(self, n: int, rng: RngLike = 0) -> np.ndarray:
        rng = _as_rng(rng)
        out = np.empty(n, dtype=np.float64)
        i, t = 0, 0.0
        while i < n:
            t += rng.exponential(1.0 / self.rate_hi)
            if rng.random() * self.rate_hi <= self.rate_at(t):
                out[i] = t
                i += 1
        return out

    @property
    def spec(self) -> str:
        return (f"ramp:{self.rate_lo!r}:{self.rate_hi!r}"
                f":{self.period!r}")

    @property
    def mean_rate(self) -> float:
        return 0.5 * (self.rate_lo + self.rate_hi)


ARRIVAL_KINDS = ("fixed", "poisson", "burst", "ramp")

_ARG_RANGES = {"fixed": (1, 1), "poisson": (1, 1),
               "burst": (1, 3), "ramp": (2, 3)}
_BUILDERS = {"fixed": FixedInterval, "poisson": PoissonProcess,
             "burst": BurstyProcess, "ramp": DiurnalRamp}


def parse_arrival(spec: Union[str, ArrivalProcess]) -> ArrivalProcess:
    """``"poisson:4"`` -> :class:`PoissonProcess(rate=4)`, etc. Accepts an
    already-built process unchanged. Raises ``ValueError`` with the
    offending spec on any malformed input."""
    if isinstance(spec, ArrivalProcess):
        return spec
    kind, _, rest = spec.partition(":")
    if kind not in _BUILDERS:
        raise ValueError(f"unknown arrival process {kind!r} in {spec!r}; "
                         f"choose from {ARRIVAL_KINDS}")
    parts = rest.split(":") if rest else []
    try:
        args = [float(p) for p in parts]
    except ValueError:
        raise ValueError(f"bad arrival spec {spec!r}: "
                         "non-numeric parameter") from None
    lo, hi = _ARG_RANGES[kind]
    if not lo <= len(args) <= hi:
        want = str(lo) if lo == hi else f"{lo}..{hi}"
        raise ValueError(f"bad arrival spec {spec!r}: {kind} takes "
                         f"{want} parameter(s), got {len(args)}")
    return _BUILDERS[kind](*args)
