"""Load sweep + SLO capacity search over the open-loop driver.

Two measurement shapes on top of :class:`~repro.workloads.driver.
OpenLoopDriver`:

  * :func:`rate_sweep` — latency-vs-QPS curves (the paper's Fig.-4
    shape): run the same workload at each requested rate on a *fresh*
    service and collect TTFT/TBT tails, goodput and queueing delay per
    point.
  * :func:`capacity_search` / :func:`find_capacity` — the number
    operators actually want: the maximum arrival rate a system sustains
    while keeping goodput (SLO attainment) at or above a target.
    Goodput is monotone non-increasing in offered load, so a bracketed
    bisection converges; the search keeps every evaluation so callers
    can plot the probe points.

Both are callable-parameterised (``make_service()`` /
``make_requests(rate)``) so any topology × workload combination sweeps
the same way — benchmarks pass ``ServeSpec(...).build`` and a
``make_trace(..., arrival=f"poisson:{rate}")`` closure.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.metrics import slo_attainment
from repro.core.request import Request
from repro.workloads.driver import OpenLoopDriver

# Latency deadlines for goodput (SLO-attainment) reporting, chosen from the
# paper's Fig. 4 operating range on the Azure-conversation trace: a request
# is "good" if its TTFT and its per-request P99 inter-token gap both land
# under these. (Canonical home of the values benchmarks/common.py
# re-exports.)
DEFAULT_TTFT_SLO = 5.0    # seconds
DEFAULT_TBT_SLO = 0.20    # seconds/token


def open_loop_measure(make_service: Callable[[], object],
                      make_requests: Callable[[float], Sequence[Request]],
                      rate: float, *,
                      ttft_slo: float = DEFAULT_TTFT_SLO,
                      tbt_slo: float = DEFAULT_TBT_SLO,
                      seed: Optional[int] = None) -> Dict[str, float]:
    """One curve point: build a fresh service, drive ``make_requests(rate)``
    open-loop, and return the aggregate with queueing keys, ``goodput``
    (unfinished submissions count as misses) and ``rate``.

    ``seed`` pins probe construction: when given, the trace factory is
    called as ``make_requests(rate, seed)`` so the same (rate, seed) pair
    builds the same request stream in every process — the determinism the
    auto-topology planner's memo relies on. ``None`` keeps the one-arg
    back-compat call."""
    service = make_service()
    reqs = list(make_requests(rate) if seed is None
                else make_requests(rate, seed))
    driver = OpenLoopDriver(service)
    driver.run(reqs)
    m = driver.metrics()
    # goodput over the submitted stream, not just the finished set, so a
    # system that sheds load can't look good by finishing only the easy part
    m["goodput"] = slo_attainment([r.metrics for r in reqs],
                                  ttft_slo, tbt_slo)
    m["rate"] = rate
    return m


def rate_sweep(make_service: Callable[[], object],
               make_requests: Callable[[float], Sequence[Request]],
               rates: Sequence[float], *,
               ttft_slo: float = DEFAULT_TTFT_SLO,
               tbt_slo: float = DEFAULT_TBT_SLO,
               seed: Optional[int] = None) -> List[Dict[str, float]]:
    """Latency-vs-QPS curve: one :func:`open_loop_measure` row per rate."""
    return [open_loop_measure(make_service, make_requests, r,
                              ttft_slo=ttft_slo, tbt_slo=tbt_slo, seed=seed)
            for r in rates]


@dataclasses.dataclass(frozen=True)
class CapacityResult:
    """Outcome of a capacity search. ``rate`` is the highest *probed* rate
    whose goodput met ``target`` (0.0 when even the lower bracket missed);
    ``evaluations`` holds every ``(rate, goodput)`` probe in order."""

    rate: float
    target: float
    evaluations: Tuple[Tuple[float, float], ...]

    @property
    def sustainable(self) -> bool:
        return self.rate > 0.0


def capacity_search(eval_goodput: Callable[[float], float],
                    lo: float, hi: float, *,
                    target: float = 0.9, rel_tol: float = 0.05,
                    max_iters: int = 12) -> CapacityResult:
    """Largest rate in ``[lo, hi]`` with ``eval_goodput(rate) >= target``.

    Assumes goodput is monotone non-increasing in rate (more offered load
    never helps the tail). Brackets first — a failing ``lo`` returns
    ``rate=0.0`` (nothing in range is sustainable) and a passing ``hi``
    returns ``hi`` (the system out-runs the whole range) — then bisects
    until the bracket is within ``rel_tol`` of the passing edge or
    ``max_iters`` probes are spent. The returned rate was always
    *actually measured* as good, never interpolated.
    """
    if lo <= 0 or hi < lo:
        raise ValueError(f"capacity_search needs 0 < lo <= hi, "
                         f"got [{lo}, {hi}]")
    if not 0.0 < target <= 1.0:
        raise ValueError(f"target must be in (0, 1], got {target}")
    evals: List[Tuple[float, float]] = []

    def probe(rate: float) -> float:
        g = float(eval_goodput(rate))
        evals.append((rate, g))
        return g

    if probe(lo) < target:
        return CapacityResult(0.0, target, tuple(evals))
    if hi == lo or probe(hi) >= target:
        return CapacityResult(hi, target, tuple(evals))
    good, bad = lo, hi
    for _ in range(max_iters):
        if (bad - good) <= rel_tol * good:
            break
        mid = 0.5 * (good + bad)
        if probe(mid) >= target:
            good = mid
        else:
            bad = mid
    return CapacityResult(good, target, tuple(evals))


def find_capacity(make_service: Callable[[], object],
                  make_requests: Callable[[float], Sequence[Request]],
                  lo: float, hi: float, *,
                  target: float = 0.9,
                  ttft_slo: float = DEFAULT_TTFT_SLO,
                  tbt_slo: float = DEFAULT_TBT_SLO,
                  rel_tol: float = 0.05,
                  max_iters: int = 12,
                  seed: Optional[int] = None) -> CapacityResult:
    """SLO-sustainable capacity of one system: :func:`capacity_search`
    with each probe a full open-loop run at that rate. ``seed`` pins
    probe construction (see :func:`open_loop_measure`) so the same
    search on the same system is bit-reproducible."""
    def eval_goodput(rate: float) -> float:
        return open_loop_measure(make_service, make_requests, rate,
                                 ttft_slo=ttft_slo,
                                 tbt_slo=tbt_slo, seed=seed)["goodput"]
    return capacity_search(eval_goodput, lo, hi, target=target,
                           rel_tol=rel_tol, max_iters=max_iters)
