"""jit'd public wrappers for the Pallas kernels: padding, alignment, fallback.

``*_auto`` functions pad C/S to block multiples and D to a multiple of 128
(MXU lane alignment), call the Pallas kernel, and unpad. ``use_pallas=False``
routes to the pure-jnp oracle (the XLA path used on CPU and in the dry-run).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.chunked_prefill_attention import chunked_prefill_attention_pallas
from repro.kernels.paged_attention import paged_decode_attention_pallas


def _pad_to(x, mult: int, axis: int):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pad_pos(x, mult: int):
    """Pad a positions array with -1 (invalid) instead of zeros."""
    pad = (-x.shape[1]) % mult
    if pad == 0:
        return x
    return jnp.pad(x, ((0, 0), (0, pad)), constant_values=-1)


@functools.partial(jax.jit, static_argnames=("window", "use_pallas",
                                             "block_q", "block_k", "interpret"))
def chunked_prefill_attention(q, k, v, q_pos, kv_pos, *, window: int = 0,
                              use_pallas: bool = False, block_q: int = 128,
                              block_k: int = 128, interpret: bool = True):
    if not use_pallas:
        return ref.chunked_prefill_attention_ref(q, k, v, q_pos, kv_pos, window)
    b, c, h, d = q.shape
    bq = min(block_q, max(8, c))
    bk = min(block_k, max(8, k.shape[1]))
    qp = _pad_to(q, bq, 1)
    kp_ = _pad_to(k, bk, 1)
    vp = _pad_to(v, bk, 1)
    d_pad = max(128, d + (-d) % 128) if d > 8 else d
    if d_pad != d:
        qp = _pad_to(qp, d_pad, 3)
        kp_ = _pad_to(kp_, d_pad, 3)
        vp = _pad_to(vp, d_pad, 3)
    qpos = _pad_pos(q_pos, bq)
    kvpos = _pad_pos(kv_pos, bk)
    # padded D lanes contribute zeros to q.k — but the softmax scale must use
    # the ORIGINAL head dim, so pass it explicitly.
    out = chunked_prefill_attention_pallas(
        qp, kp_, vp, qpos, kvpos, window=window, block_q=bq, block_k=bk,
        scale=d ** -0.5, interpret=interpret)
    return out[:, :c, :, :d]


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def paged_decode_attention(q, k_pages, v_pages, block_tables, context_lens, *,
                           use_pallas: bool = False, interpret: bool = True):
    if not use_pallas:
        return ref.paged_decode_attention_ref(q, k_pages, v_pages,
                                              block_tables, context_lens)
    return paged_decode_attention_pallas(q, k_pages, v_pages, block_tables,
                                         context_lens, interpret=interpret)
