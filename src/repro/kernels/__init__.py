"""Attention kernels for the real execution paths.

Public API (what :class:`repro.core.executor.PagedRealExecutor` and the
model stacks call):

``ops.chunked_prefill_attention(q, k, v, q_pos, kv_pos, *, window=0,
use_pallas=False)``
    Chunked-prefill attention over a gathered KV window. ``q`` is
    ``[B, C, H, D]`` (the current chunk), ``k``/``v`` are ``[B, S, Kv, D]``
    where ``S`` covers every token written so far (for the paged executor:
    the request's block table gathered flat, so ``S = n_pages *
    page_size``). ``q_pos``/``kv_pos`` are absolute positions with ``-1``
    marking padding; a kv token attends iff ``0 <= kv_pos <= q_pos``
    (causal), windowed variants additionally require ``q_pos - kv_pos <
    window``. ``use_pallas=False`` dispatches the pure-jnp reference
    (CPU/CI); ``True`` the Pallas TPU kernel (``interpret=True`` runs it
    on CPU).

``ops.paged_decode_attention(q, k_pages, v_pages, block_tables,
context_lens, *, use_pallas=False)``
    One decode step over block-pooled KV. ``q`` is ``[B, H, D]``,
    ``k_pages``/``v_pages`` are the physical pool ``[num_pages,
    page_size, Kv, D]``, ``block_tables`` is ``[B, max_pages]`` of pool
    page ids (rows may be padded with any in-range page id — masking is
    by length, not id), and ``context_lens[b]`` counts the valid tokens
    of row ``b``: position ``p`` of its table is attended iff
    ``p < context_lens[b]``, so a partial last page is handled by length
    alone. No sliding-window support.

``paged_decode_attention_pallas`` / ``chunked_prefill_attention_pallas``
    The raw Pallas kernels behind ``use_pallas=True`` — fixed tile-size
    contracts, no padding convenience; prefer the ``ops`` wrappers.

``chunked_prefill_attention_ref`` / ``paged_decode_attention_ref``
    Pure-jnp references the property tests check the kernels against.

This layer exists because the paper's serving results ride on paged
attention: the engine's :class:`~repro.kvcache.allocator.BlockAllocator`
block tables are the *same* tables these kernels consume, which is what
makes prefix-cache hits and Cronus PPI→CPI handoffs free at the compute
level (block-id remaps, no KV copies).
"""
from repro.kernels import ops
from repro.kernels.chunked_prefill_attention import \
    chunked_prefill_attention_pallas
from repro.kernels.ops import chunked_prefill_attention, paged_decode_attention
from repro.kernels.paged_attention import paged_decode_attention_pallas
from repro.kernels.ref import (chunked_prefill_attention_ref,
                               paged_decode_attention_ref)

__all__ = [
    "ops",
    "chunked_prefill_attention",
    "paged_decode_attention",
    "chunked_prefill_attention_pallas",
    "paged_decode_attention_pallas",
    "chunked_prefill_attention_ref",
    "paged_decode_attention_ref",
]
