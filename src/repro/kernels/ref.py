"""Pure-jnp oracles for the Pallas kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def chunked_prefill_attention_ref(q, k, v, q_pos, kv_pos, window: int = 0):
    """Flash-attention oracle for a prefill chunk against a (partial) cache.

    q:      [B, C, H, D]   chunk queries
    k, v:   [B, S, Kv, D]  KV cache contents (chunk already written)
    q_pos:  [B, C] int32   absolute positions of chunk tokens
    kv_pos: [B, S] int32   absolute positions of cache slots (-1 = empty)
    window: sliding window (0 = full causal)
    -> [B, C, H, D]
    """
    b, c, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, c, kvh, g, d).astype(jnp.float32)
    scores = jnp.einsum("bckgd,bskd->bckgs", qg, k.astype(jnp.float32)) * d ** -0.5
    valid = (kv_pos[:, None, :] >= 0) & (kv_pos[:, None, :] <= q_pos[:, :, None])
    if window > 0:
        valid &= kv_pos[:, None, :] > q_pos[:, :, None] - window
    scores = jnp.where(valid[:, :, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bckgs,bskd->bckgd", probs, v.astype(jnp.float32))
    return out.reshape(b, c, h, d).astype(q.dtype)


def paged_decode_attention_ref(q, k_pages, v_pages, block_tables, context_lens):
    """Decode attention over a paged KV cache.

    q:            [B, H, D]
    k/v_pages:    [P, page, Kv, D]
    block_tables: [B, max_pages] int32 (page ids; padding entries arbitrary)
    context_lens: [B] int32
    -> [B, H, D]
    """
    b, h, d = q.shape
    p, page, kvh, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    g = h // kvh
    # gather per-request KV: [B, max_pages*page, Kv, D]
    kk = k_pages[block_tables].reshape(b, max_pages * page, kvh, d)
    vv = v_pages[block_tables].reshape(b, max_pages * page, kvh, d)
    pos = jnp.arange(max_pages * page)[None, :]
    valid = pos < context_lens[:, None]
    qg = q.reshape(b, kvh, g, d).astype(jnp.float32)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, kk.astype(jnp.float32)) * d ** -0.5
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, vv.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)
