"""Pallas TPU kernel: decode attention over a paged KV cache.

vLLM's PagedAttention is a CUDA gather kernel; the TPU-native rethink uses
*scalar prefetch*: block tables are prefetched to SMEM, and the BlockSpec
index_map dereferences them so the DMA engine streams exactly the pages a
request owns from HBM into VMEM, ahead of compute. Grid = (B, Kv, pages)
with pages innermost (sequential), flash statistics accumulated in VMEM
scratch, output emitted on the final page.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(block_tables_ref, context_lens_ref,   # scalar prefetch
            q_ref, k_ref, v_ref,                  # VMEM tiles
            o_ref,
            m_ref, l_ref, acc_ref,
            *, scale: float, page: int):
    bi = pl.program_id(0)
    pi = pl.program_id(2)
    np_ = pl.num_programs(2)

    @pl.when(pi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ctx = context_lens_ref[bi]
    # skip pages entirely beyond the context
    @pl.when(pi * page < ctx)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [G, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)    # [page, D]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jnp.dot(q, k.T) * scale                  # [G, page]
        pos = pi * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        valid = pos < ctx
        s = jnp.where(valid, s, NEG_INF)
        m_prev, l_prev = m_ref[:, 0], l_ref[:, 0]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(p, v)
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(pi == np_ - 1)
    def _emit():
        l_fin = l_ref[:, 0]
        safe = jnp.where(l_fin == 0.0, 1.0, l_fin)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def paged_decode_attention_pallas(q, k_pages, v_pages, block_tables,
                                  context_lens, *, interpret: bool = True):
    """q [B,H,D]; k/v_pages [P,page,Kv,D]; block_tables [B,max_pages];
    context_lens [B] -> [B,H,D]."""
    b, h, d = q.shape
    p_total, page, kvh, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    g = h // kvh
    qg = q.reshape(b, kvh, g, d)

    grid = (b, kvh, max_pages)
    kernel = functools.partial(_kernel, scale=d ** -0.5, page=page)

    def kv_index(bi, kvi, pi, bt_ref, cl_ref):
        return (bt_ref[bi, pi], 0, kvi, 0)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, d),
                             lambda bi, kvi, pi, *_: (bi, kvi, 0, 0)),
                pl.BlockSpec((1, page, 1, d), kv_index),
                pl.BlockSpec((1, page, 1, d), kv_index),
            ],
            out_specs=pl.BlockSpec((1, 1, g, d),
                                   lambda bi, kvi, pi, *_: (bi, kvi, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, 128), jnp.float32),
                pltpu.VMEM((g, 128), jnp.float32),
                pltpu.VMEM((g, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), q.dtype),
        interpret=interpret,
    )(block_tables, context_lens, qg, k_pages, v_pages)
    return out.reshape(b, h, d)
