"""Pallas TPU kernel: flash attention for a chunked-prefill step.

The CPI's hot loop (paper §4.4) is a batch mixing one prefill *chunk* with
decode tokens; the prefill chunk's attention against (cached context +
itself) dominates compute. This kernel computes that: a query chunk
``[C, H, D]`` attends to the KV cache ``[S, Kv, D]`` with causal masking by
*absolute position* (the chunk's offset into the request rides in
``q_pos``), an optional sliding window, and GQA head grouping.

TPU mapping: grid = (B, Kv, C/bq, S/bk) with the KV axis innermost
(sequential on TPU), running flash statistics (m, l, acc) in fp32 VMEM
scratch, output written on the final KV step. Query tiles fold the GQA
group dim (rows = bq*G); D is padded to a multiple of 128 in ops.py so the
MXU matmuls are hardware-aligned. Positions arrive via scalar prefetch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_pos_ref, kv_pos_ref,           # scalar-prefetch refs (full arrays)
            q_ref, k_ref, v_ref,             # VMEM tiles
            o_ref,                           # output tile
            m_ref, l_ref, acc_ref,           # fp32 scratch
            *, scale: float, window: int, bq: int, bk: int):
    bi = pl.program_id(0)
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                            # [bq, G, D]
    g, d = q.shape[1], q.shape[2]
    q2 = q.reshape(bq * g, d).astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)        # [bk, D]
    v = v_ref[0, 0].astype(jnp.float32)        # [bk, D]

    s = jnp.dot(q2, k.T) * scale               # [bq*G, bk]

    qp = q_pos_ref[bi, pl.ds(qi * bq, bq)]     # [bq]
    kp = kv_pos_ref[bi, pl.ds(ki * bk, bk)]    # [bk]
    qp2 = jnp.repeat(qp, g)                    # [bq*G]
    valid = (kp[None, :] >= 0) & (kp[None, :] <= qp2[:, None])
    if window > 0:
        valid &= kp[None, :] > qp2[:, None] - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[:, 0]
    l_prev = l_ref[:, 0]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(valid, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(p, v)
    m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ki == nk - 1)
    def _emit():
        l_fin = l_ref[:, 0]
        safe = jnp.where(l_fin == 0.0, 1.0, l_fin)
        out = acc_ref[...] / safe[:, None]
        o_ref[0, 0] = out.reshape(bq, g, d).astype(o_ref.dtype)


def chunked_prefill_attention_pallas(q, k, v, q_pos, kv_pos, *,
                                     window: int = 0,
                                     block_q: int = 128, block_k: int = 128,
                                     scale: float | None = None,
                                     interpret: bool = True):
    """q [B,C,H,D]; k,v [B,S,Kv,D]; q_pos [B,C]; kv_pos [B,S] -> [B,C,H,D].

    Requires C % block_q == 0 and S % block_k == 0 after clamping
    (ops.py pads inputs and unpads the result).
    """
    b, c, h, d = q.shape
    s, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, c, kvh, g, d).transpose(0, 2, 1, 3, 4)  # [B,Kv,C,G,D]
    kt = k.transpose(0, 2, 1, 3)                              # [B,Kv,S,D]
    vt = v.transpose(0, 2, 1, 3)

    bq = min(block_q, c)
    bk = min(block_k, s)
    assert c % bq == 0 and s % bk == 0, (c, bq, s, bk)
    grid = (b, kvh, c // bq, s // bk)
    rows = bq * g

    kernel = functools.partial(_kernel, scale=scale or d ** -0.5, window=window,
                               bq=bq, bk=bk)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, bq, g, d),
                             lambda bi, kvi, qi, ki, *_: (bi, kvi, qi, 0, 0)),
                pl.BlockSpec((1, 1, bk, d),
                             lambda bi, kvi, qi, ki, *_: (bi, kvi, ki, 0)),
                pl.BlockSpec((1, 1, bk, d),
                             lambda bi, kvi, qi, ki, *_: (bi, kvi, ki, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, bq, g, d),
                                   lambda bi, kvi, qi, ki, *_: (bi, kvi, qi, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((rows, 128), jnp.float32),
                pltpu.VMEM((rows, 128), jnp.float32),
                pltpu.VMEM((rows, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kvh, c, g, d), q.dtype),
        interpret=interpret,
    )(q_pos, kv_pos, qg, kt, vt)
    return out.transpose(0, 2, 1, 3, 4).reshape(b, c, h, d)
