"""Candidate space for the auto-topology planner.

Three pieces turn a :class:`~repro.autoscale.inventory.DeviceInventory`
into a searchable, finite topology space:

  * :class:`WorkloadSpec` — the workload the planner optimises *for*
    (trace family, arrival process, SLOs, goodput target), with a
    round-trippable spec string (``"azure:poisson:n=80:scale=0.05"``)
    that doubles as the evaluation-memo key prefix;
  * :func:`enumerate_layouts` — every endpoint multiset buildable from
    the inventory, as *canonical* topology-DSL strings, pruned by the
    paper's structure (pairs only pair a faster device's decode engine
    with a strictly slower prefill device — the PPI/CPI asymmetry of
    §3 — and fan-out is capped) and deduped so isomorphic layouts are
    enumerated once;
  * :class:`Candidate` — one (layout, router) point of the space, priced
    in A100-equivalent device-seconds through the same
    :class:`~repro.autoscale.inventory.DeviceLedger` the autoscaler
    benchmarks settle cost with.

Pruning rules (why the space stays small):
  * **pair asymmetry** — ``cronus``/``disagg`` nodes are only generated
    as ``KIND:FAST+SLOW`` with ``flops(FAST) > flops(SLOW)``; a
    homogeneous or inverted pair is never a Cronus win (the PPI exists
    to offload prefill *from* the stronger decode device).
  * **fan-out cap** — layouts stop at ``max_endpoints`` routable nodes;
    beyond the cap, additional endpoints only dilute the router's
    choices at quick-rig scales.
  * **canonical dedupe** — every layout is rendered through
    :func:`~repro.cluster.topology.canonical_cluster_spec`, so
    ``"worker:A10,cronus:A100+A10"`` and ``"cronus:A100+A10,worker:A10"``
    cost one evaluation, not two.
  * **idle devices allowed** — a layout need not consume the rack; the
    objective is capacity *per device-cost*, and the strongest move is
    often leaving weak devices idle.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.autoscale.inventory import DeviceInventory, DeviceLedger, \
    heuristic_capacity_qps
from repro.cluster.router import ROUTERS
from repro.cluster.topology import canonical_cluster_spec, parse_cluster_spec
from repro.scheduling import SCHEDULERS
from repro.serving.hardware import DEVICES
from repro.serving.trace import Trace, make_shared_prefix_trace, make_trace
from repro.workloads.sweep import DEFAULT_TBT_SLO, DEFAULT_TTFT_SLO

TRACE_KINDS = ("azure", "shared_prefix")
ARRIVAL_KINDS = ("poisson", "burst", "fixed")

# pair kinds the enumerator may generate (all obey the fast+slow rule)
PAIR_KINDS = ("cronus", "disagg_lh", "disagg_hl")


# ---------------------------------------------------------------------------
# the workload half of the planning problem
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """What the planner optimises for: a trace family driven by an
    arrival process against SLO targets. The ``rate`` axis is left free —
    ``find_capacity`` owns it — so one ``WorkloadSpec`` describes the
    whole load curve, and :meth:`make_requests` materialises the probe
    trace at any rate, deterministically per ``seed``."""

    trace: str = "azure"          # trace family (TRACE_KINDS)
    arrival: str = "poisson"      # arrival process family (ARRIVAL_KINDS)
    n_requests: int = 100         # requests per capacity probe
    seed: int = 0                 # probe-trace seed (determinism anchor)
    scale: float = 1.0            # length scale (shrink for CPU-rig runs)
    ttft_slo: float = DEFAULT_TTFT_SLO
    tbt_slo: float = DEFAULT_TBT_SLO
    target: float = 0.9           # goodput the capacity search must hold

    def __post_init__(self):
        if self.trace not in TRACE_KINDS:
            raise ValueError(f"unknown trace kind {self.trace!r}; "
                             f"choose from {TRACE_KINDS}")
        if self.arrival not in ARRIVAL_KINDS:
            raise ValueError(f"unknown arrival kind {self.arrival!r}; "
                             f"choose from {ARRIVAL_KINDS}")
        if self.n_requests < 1:
            raise ValueError("n_requests must be >= 1")
        if not 0 < self.scale <= 1.0:
            raise ValueError(f"scale must be in (0, 1], got {self.scale}")
        if not 0.0 < self.target <= 1.0:
            raise ValueError(f"target must be in (0, 1], got {self.target}")

    # -- spec-string round-trip (the AutoscalePolicy idiom) --------------
    @property
    def spec(self) -> str:
        """Compact string; ``parse_workload(w.spec) == w``. Only
        non-default fields are emitted, so the default workload is just
        ``"azure:poisson"``."""
        default = WorkloadSpec()
        parts = [self.trace, self.arrival]
        for key, field in _WORKLOAD_KEYS.items():
            if getattr(self, field) != getattr(default, field):
                parts.append(f"{key}={getattr(self, field)!r}")
        return ":".join(parts)

    def arrival_spec(self, rate: float) -> str:
        """The :mod:`repro.workloads.arrivals` spec at offered ``rate``."""
        if rate <= 0:
            raise ValueError(f"rate must be > 0, got {rate}")
        if self.arrival == "poisson":
            return f"poisson:{rate!r}"
        if self.arrival == "burst":
            return f"burst:{rate!r}"
        return f"fixed:{1.0 / rate!r}"

    def make_requests(self, rate: float,
                      seed: Optional[int] = None) -> Trace:
        """The probe trace at offered ``rate`` — the two-arg factory
        :func:`repro.workloads.find_capacity` calls when given a seed.
        Same (rate, seed) ⇒ byte-identical trace in any process."""
        seed = self.seed if seed is None else seed
        kw = dict(seed=seed, arrival=self.arrival_spec(rate),
                  scale=self.scale)
        if self.trace == "shared_prefix":
            return make_shared_prefix_trace(self.n_requests, **kw)
        return make_trace(self.n_requests, **kw)


# spec-string key -> WorkloadSpec field (trace/arrival are positional)
_WORKLOAD_KEYS = {
    "n": "n_requests",
    "seed": "seed",
    "scale": "scale",
    "ttft": "ttft_slo",
    "tbt": "tbt_slo",
    "target": "target",
}


def parse_workload(spec: "str | WorkloadSpec") -> WorkloadSpec:
    """Inverse of :attr:`WorkloadSpec.spec`, with one-line refusals that
    name the offending part."""
    if isinstance(spec, WorkloadSpec):
        return spec
    parts = [p for p in spec.split(":") if p]
    if len(parts) < 2:
        raise ValueError(f"bad workload spec {spec!r}: expected "
                         "TRACE:ARRIVAL[:key=value...], e.g. "
                         "'azure:poisson:n=80:scale=0.05'")
    kw: Dict = {"trace": parts[0], "arrival": parts[1]}
    fields = {f.name: f.type for f in dataclasses.fields(WorkloadSpec)}
    for part in parts[2:]:
        key, sep, val = part.partition("=")
        if not sep or key not in _WORKLOAD_KEYS:
            raise ValueError(f"bad workload option {part!r} in {spec!r}; "
                             f"known keys: {sorted(_WORKLOAD_KEYS)}")
        field = _WORKLOAD_KEYS[key]
        caster = int if fields[field] == "int" else float
        try:
            kw[field] = caster(val)
        except ValueError:
            raise ValueError(f"bad workload value {part!r} in {spec!r}: "
                             f"expected {caster.__name__}") from None
    return WorkloadSpec(**kw)


# ---------------------------------------------------------------------------
# the topology half: layouts and candidates
# ---------------------------------------------------------------------------

def layout_devices(layout: str) -> Tuple[str, ...]:
    """Every device instance a layout occupies (with multiplicity)."""
    spec = parse_cluster_spec(layout)
    return tuple(d for node in spec.nodes
                 for _ in range(node.count) for d in node.devices)


def layout_cost_rate(layout: str) -> float:
    """A100-equivalent device-seconds one second of this layout costs —
    priced through :class:`DeviceLedger`, the same meter the autoscale
    benchmarks settle with, so planner scores and fleet benchmarks share
    one cost axis."""
    ledger = DeviceLedger()
    ledger.open("layout", layout_devices(layout), 0.0)
    return ledger.device_cost(1.0)


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the search space: a canonical layout behind one
    router. Hashable, so beams and memo keys use it directly."""

    cluster: str                 # canonical topology-DSL string
    router: str = "least_loaded"

    def __post_init__(self):
        object.__setattr__(self, "cluster",
                           canonical_cluster_spec(self.cluster))
        if self.router not in ROUTERS:
            raise ValueError(f"unknown router {self.router!r}; "
                             f"choose from {sorted(ROUTERS)}")

    @property
    def devices(self) -> Tuple[str, ...]:
        return layout_devices(self.cluster)

    @property
    def cost_rate(self) -> float:
        return layout_cost_rate(self.cluster)

    @property
    def n_endpoints(self) -> int:
        return sum(n.count for n in parse_cluster_spec(self.cluster).nodes)

    @property
    def capacity_prior(self) -> float:
        """FLOPS-proportional capacity guess (probe-ordering heuristic)."""
        return heuristic_capacity_qps(self.devices)


def node_templates(inventory: DeviceInventory,
                   pair_kinds: Sequence[str] = ("cronus",),
                   ) -> List[Tuple[str, Tuple[str, ...]]]:
    """The single-endpoint building blocks an inventory supports, as
    ``(node_dsl, devices)`` sorted fastest-first: one standalone worker
    per device type, plus each requested pair kind over every strictly
    flops-asymmetric (fast, slow) type pair — the PPI/CPI pruning rule."""
    for kind in pair_kinds:
        if kind not in PAIR_KINDS:
            raise ValueError(f"unknown pair kind {kind!r}; "
                             f"choose from {PAIR_KINDS}")
    types = sorted(inventory.counts, key=lambda d: (-DEVICES[d].flops, d))
    out: List[Tuple[str, Tuple[str, ...]]] = []
    for t in types:
        out.append((f"worker:{t}", (t,)))
    for i, hi in enumerate(types):
        for lo in types[i + 1:]:
            if DEVICES[hi].flops <= DEVICES[lo].flops:
                continue      # equal-flops types: no asymmetry to exploit
            for kind in pair_kinds:
                out.append((f"{kind}:{hi}+{lo}", (hi, lo)))
    return out


def enumerate_layouts(inventory: DeviceInventory, *,
                      max_endpoints: int = 4,
                      pair_kinds: Sequence[str] = ("cronus",),
                      require_full_rack: bool = False) -> List[str]:
    """Every layout buildable from ``inventory`` with at most
    ``max_endpoints`` endpoints, as sorted canonical DSL strings.

    The recursion walks templates in a fixed order and only ever *adds*
    instances of the current-or-later template, so each multiset is
    generated once; canonicalisation then collapses whatever symmetry
    remains. ``require_full_rack=True`` keeps only layouts that consume
    the whole inventory (the hand-baseline shape); the default allows
    idle devices because the objective is capacity per device-cost."""
    if max_endpoints < 1:
        raise ValueError("max_endpoints must be >= 1")
    templates = node_templates(inventory, pair_kinds)
    seen: Dict[str, None] = {}

    def rec(idx: int, remaining: DeviceInventory, nodes: List[str]):
        if nodes:
            if not require_full_rack or remaining.total == 0:
                seen[canonical_cluster_spec(",".join(nodes))] = None
        if len(nodes) >= max_endpoints:
            return
        for j in range(idx, len(templates)):
            node, devices = templates[j]
            if not remaining.can_build(devices):
                continue
            remaining.take(devices)
            nodes.append(node)
            rec(j, remaining, nodes)
            nodes.pop()
            remaining.put(devices)

    rec(0, DeviceInventory(dict(inventory.counts)), [])
    return sorted(seen)


def router_choices(layout: str,
                   routers: Sequence[str] = ("round_robin", "least_loaded"),
                   ) -> Tuple[str, ...]:
    """Routers worth probing for a layout. A single-endpoint layout has
    nothing to route — ``round_robin`` only; affinity routers
    (``prefix_affinity``/``kv_aware``/``session``) are withheld unless
    some node actually caches (``@cache``), since without KV reuse they
    degenerate to round-robin at extra probe cost."""
    for r in routers:
        if r not in ROUTERS:
            raise ValueError(f"unknown router {r!r}; "
                             f"choose from {sorted(ROUTERS)}")
    spec = parse_cluster_spec(layout)
    if sum(n.count for n in spec.nodes) == 1:
        return ("round_robin",)
    affinity = {"prefix_affinity", "kv_aware", "session"}
    cached = any(n.options.get("prefix_cache") for n in spec.nodes)
    kept = tuple(r for r in routers if cached or r not in affinity)
    return kept or ("least_loaded",)


def suffix_variants(layout: str, *,
                    policies: Sequence[str] = ("sarathi",),
                    cache: bool = False) -> List[str]:
    """Refinement moves on one layout: the layout with a uniform
    ``@policy`` suffix per requested policy, and (``cache=True``) each of
    those plus ``@cache`` on every node. Canonical, deduped, and never
    including the unmodified layout itself."""
    for p in policies:
        if p not in SCHEDULERS:
            raise ValueError(f"unknown sched policy {p!r}; "
                             f"choose from {sorted(SCHEDULERS)}")
    spec = parse_cluster_spec(layout)
    variants: Dict[str, None] = {}

    def emit(policy: Optional[str], cached: bool):
        nodes = []
        for n in spec.nodes:
            opts = dict(n.options)
            if policy is not None:
                opts["sched_policy"] = policy
            if cached:
                opts["prefix_cache"] = True
            nodes.append(dataclasses.replace(n, options=opts))
        text = ",".join(n.spec for n in nodes)
        variants[canonical_cluster_spec(text)] = None

    for policy in policies:
        emit(policy, False)
    if cache:
        emit(None, True)
        for policy in policies:
            emit(policy, True)
    base = canonical_cluster_spec(layout)
    return sorted(v for v in variants if v != base)
