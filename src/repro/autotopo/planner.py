"""Greedy-constructive topology search with beam refinement over
``find_capacity`` probes.

The planner answers the operator question HexGen-2 frames as placement
optimization: *given this rack and this workload, what topology should I
serve?* Objective: SLO-sustainable capacity per A100-equivalent
device-second (``CapacityResult.rate / layout_cost_rate``), so a layout
only earns its devices — leaving a weak GPU idle beats attaching it
where it dilutes cost-efficiency.

Search shape (both phases measure, never estimate):

  **Phase A — greedy construction.** Start from the empty layout and
  repeatedly extend each beam layout by one node template the remaining
  inventory can build. Every extension is measured with
  :func:`~repro.workloads.find_capacity` and the ``beam_width`` best
  layouts survive to the next round; construction stops when no
  extension improves on the incumbent best or the endpoint cap is hit.
  Greedy-with-beam covers the layout lattice without the exponential
  sweep of full enumeration, and keeps every measured point as a ranked
  candidate.

  **Phase B — refinement.** The ``refine_top`` best layouts are crossed
  with router choices and ``@policy``/``@cache`` suffix variants
  (:func:`~repro.autotopo.space.suffix_variants`) — the cheap,
  structure-preserving moves — and re-measured.

Every probe goes through :class:`EvalMemo`, keyed on the *canonical* DSL
string + router + workload spec + probe-bracket parameters. The memo
round-trips to JSON, so a re-planned or CI-resumed search re-runs zero
completed probes, and two spellings of one topology never cost two
measurements. Determinism: enumeration order is sorted, ties break on
the canonical string, and probe traces are seeded — same inventory +
workload + seed ⇒ the same ranked plan, bit for bit.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.autoscale.inventory import DeviceInventory
from repro.autotopo.space import Candidate, WorkloadSpec, \
    layout_cost_rate, node_templates, parse_workload, router_choices, \
    suffix_variants
from repro.cluster.topology import canonical_cluster_spec
from repro.workloads.sweep import CapacityResult, find_capacity


class EvalMemo:
    """Persistent probe cache: (workload, canonical layout, router,
    bracket) -> :class:`CapacityResult`. The bracket parameters are part
    of the key, so a search with different probe settings never reuses a
    stale measurement; JSON round-trip (:meth:`save`/:meth:`load`) lets
    re-planning and CI skip every completed probe."""

    def __init__(self, entries: Optional[Dict[str, Dict]] = None):
        self._entries: Dict[str, Dict] = dict(entries or {})
        self.hits = 0
        self.misses = 0

    @staticmethod
    def key(workload: WorkloadSpec, candidate: Candidate,
            bracket: Dict[str, float]) -> str:
        probe = ",".join(f"{k}={bracket[k]!r}" for k in sorted(bracket))
        return (f"{workload.spec}|{candidate.cluster}"
                f"|{candidate.router}|{probe}")

    def get(self, key: str) -> Optional[CapacityResult]:
        e = self._entries.get(key)
        if e is None:
            return None
        return CapacityResult(rate=e["rate"], target=e["target"],
                              evaluations=tuple(
                                  (r, g) for r, g in e["evaluations"]))

    def put(self, key: str, result: CapacityResult) -> None:
        self._entries[key] = {
            "rate": result.rate, "target": result.target,
            "evaluations": [list(e) for e in result.evaluations],
        }

    def __len__(self) -> int:
        return len(self._entries)

    def to_dict(self) -> Dict:
        return {"entries": self._entries}

    @classmethod
    def from_dict(cls, d: Dict) -> "EvalMemo":
        return cls(d.get("entries", {}))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> "EvalMemo":
        with open(path) as f:
            return cls.from_dict(json.load(f))


@dataclasses.dataclass(frozen=True)
class PlanCandidate:
    """One measured point of the plan: a candidate with its probe
    outcome and cost accounting."""

    cluster: str
    router: str
    capacity_qps: float       # find_capacity's sustained rate (0 = unsustainable)
    cost_rate: float          # A100-equivalents per second (DeviceLedger pricing)
    score: float              # capacity per cost — the ranking objective
    n_probes: int             # open-loop runs this measurement took
    from_memo: bool           # True when the memo supplied it probe-free

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class PlanResult:
    """Ranked outcome of one planner run. ``ranked[0]`` is the
    recommendation; ``probes`` is the full measurement history in probe
    order (rate/goodput pairs flattened per candidate) for plotting the
    search trajectory."""

    inventory: str                      # the rack searched
    workload: str                       # WorkloadSpec.spec
    ranked: List[PlanCandidate]
    probes: List[Dict]                  # history rows, probe order
    n_evaluations: int                  # capacity measurements run live
    n_memo_hits: int                    # measurements served by the memo
    spec_kw: Dict = dataclasses.field(default_factory=dict)

    @property
    def best(self) -> PlanCandidate:
        if not self.ranked:
            raise ValueError("empty plan: no candidate was measured")
        return self.ranked[0]

    def to_dict(self) -> Dict:
        return {
            "inventory": self.inventory, "workload": self.workload,
            "ranked": [c.to_dict() for c in self.ranked],
            "probes": self.probes,
            "n_evaluations": self.n_evaluations,
            "n_memo_hits": self.n_memo_hits,
            "spec_kw": dict(self.spec_kw),
        }

    def summary(self, top: int = 5) -> str:
        """Human-readable ranking table for ``serve.py --plan``."""
        lines = [
            f"plan for rack [{self.inventory}] on workload "
            f"[{self.workload}]",
            f"{len(self.ranked)} candidates measured "
            f"({self.n_evaluations} live, {self.n_memo_hits} from memo)",
            f"{'rank':>4}  {'cap qps':>8}  {'cost':>6}  {'score':>7}  "
            f"router / topology",
        ]
        for i, c in enumerate(self.ranked[:top], start=1):
            lines.append(f"{i:>4}  {c.capacity_qps:>8.3f}  "
                         f"{c.cost_rate:>6.2f}  {c.score:>7.3f}  "
                         f"{c.router} / {c.cluster}")
        return "\n".join(lines)


class TopologyPlanner:
    """See the module docstring for the search shape. ``spec_kw`` is
    forwarded into every probe's :class:`~repro.serving.api.ServeSpec`
    (arch/smoke/executor knobs); the plan records it so
    ``ServeSpec.from_plan`` reproduces probe conditions exactly."""

    def __init__(self, inventory: "DeviceInventory | str",
                 workload: "WorkloadSpec | str", *,
                 beam_width: int = 2,
                 refine_top: int = 2,
                 max_endpoints: int = 4,
                 pair_kinds: Sequence[str] = ("cronus",),
                 routers: Sequence[str] = ("round_robin", "least_loaded"),
                 policies: Sequence[str] = ("sarathi",),
                 try_cache: Optional[bool] = None,
                 probe_lo: float = 0.25,
                 probe_hi: Optional[float] = None,
                 rel_tol: float = 0.15,
                 max_iters: int = 6,
                 memo: Optional[EvalMemo] = None,
                 spec_kw: Optional[Dict] = None,
                 make_service: Optional[Callable] = None):
        if isinstance(inventory, str):
            inventory = DeviceInventory.parse(inventory)
        if inventory.total == 0:
            raise ValueError("cannot plan over an empty rack — give a "
                             "non-empty inventory like 'A100:1,A10:2'")
        if beam_width < 1 or refine_top < 0:
            raise ValueError("beam_width must be >= 1 and refine_top >= 0")
        self.inventory = inventory
        self.workload = parse_workload(workload)
        self.beam_width = beam_width
        self.refine_top = refine_top
        self.max_endpoints = max_endpoints
        self.pair_kinds = tuple(pair_kinds)
        self.routers = tuple(routers)
        self.policies = tuple(policies)
        # @cache only pays on shared-prefix workloads; let the workload
        # decide unless the caller forces it
        self.try_cache = (self.workload.trace == "shared_prefix"
                          if try_cache is None else try_cache)
        self.probe_lo = probe_lo
        self.probe_hi = probe_hi
        self.rel_tol = rel_tol
        self.max_iters = max_iters
        self.memo = memo if memo is not None else EvalMemo()
        # non-smoke null-executor probes: the roofline cost model needs the
        # real arch's FLOPs for capacities to mean anything (the smoke
        # config's iteration times are overhead-dominated and never
        # saturate); simulation speed is iteration-count-bound either way
        self.spec_kw = dict(spec_kw or {})
        self._make_service = make_service
        self.probes: List[Dict] = []
        self._measured: Dict[Candidate, PlanCandidate] = {}
        self.n_evaluations = 0

    # ------------------------------------------------------------------
    # one measured point
    # ------------------------------------------------------------------
    def _bracket(self, candidate: Candidate) -> Dict[str, float]:
        hi = self.probe_hi
        if hi is None:
            # FLOPS-prior-derived upper bracket: generous enough that the
            # bisection, not the bracket, finds the edge (a saturated
            # bracket would score every layout identically, because
            # UNIT_COST is itself flops-proportional); deterministic per
            # layout so memo keys are stable
            hi = max(12.0 * candidate.capacity_prior, 2.0 * self.probe_lo)
        return {"lo": self.probe_lo, "hi": hi, "rel_tol": self.rel_tol,
                "max_iters": float(self.max_iters),
                "seed": float(self.workload.seed)}

    def _service_factory(self, candidate: Candidate) -> Callable[[], object]:
        if self._make_service is not None:
            return lambda: self._make_service(candidate)
        from repro.serving.api import ServeSpec
        spec = ServeSpec(cluster=candidate.cluster, router=candidate.router,
                         **self.spec_kw)
        return spec.build

    def evaluate(self, candidate: Candidate) -> PlanCandidate:
        """Measure one candidate (memo first), recording the probe row."""
        if candidate in self._measured:
            return self._measured[candidate]
        bracket = self._bracket(candidate)
        key = EvalMemo.key(self.workload, candidate, bracket)
        result = self.memo.get(key)
        from_memo = result is not None
        if from_memo:
            self.memo.hits += 1
        else:
            self.memo.misses += 1
            self.n_evaluations += 1
            w = self.workload
            result = find_capacity(
                self._service_factory(candidate), w.make_requests,
                bracket["lo"], bracket["hi"], target=w.target,
                ttft_slo=w.ttft_slo, tbt_slo=w.tbt_slo,
                rel_tol=self.rel_tol, max_iters=self.max_iters,
                seed=w.seed)
            self.memo.put(key, result)
        cost = layout_cost_rate(candidate.cluster)
        pc = PlanCandidate(
            cluster=candidate.cluster, router=candidate.router,
            capacity_qps=result.rate, cost_rate=cost,
            score=result.rate / cost, n_probes=len(result.evaluations),
            from_memo=from_memo)
        self._measured[candidate] = pc
        self.probes.append({
            "cluster": candidate.cluster, "router": candidate.router,
            "evaluations": [list(e) for e in result.evaluations],
            "capacity_qps": result.rate, "score": pc.score,
            "from_memo": from_memo,
        })
        return pc

    # ------------------------------------------------------------------
    # the search
    # ------------------------------------------------------------------
    def _default_candidate(self, layout: str) -> Candidate:
        return Candidate(layout, router_choices(layout, self.routers)[0])

    def _extensions(self, layout: Optional[str]) -> List[str]:
        """Layouts reachable from ``layout`` by adding one buildable node
        (canonical, deduped, sorted — the determinism anchor)."""
        remaining = DeviceInventory(dict(self.inventory.counts))
        nodes: List[str] = []
        if layout:
            from repro.cluster.topology import parse_cluster_spec
            spec = parse_cluster_spec(layout)
            if sum(n.count for n in spec.nodes) >= self.max_endpoints:
                return []
            for n in spec.nodes:
                for _ in range(n.count):
                    remaining.take(n.devices)
                    nodes.append(dataclasses.replace(n, count=1).spec)
        out: Dict[str, None] = {}
        for node, devices in node_templates(self.inventory, self.pair_kinds):
            if remaining.can_build(devices):
                out[canonical_cluster_spec(",".join(nodes + [node]))] = None
        return sorted(out)

    def plan(self) -> PlanResult:
        """Run both phases and return the ranked plan."""
        # -- Phase A: greedy construction under a beam ------------------
        beam: List[Tuple[PlanCandidate, str]] = []
        frontier = self._extensions(None)
        best_score = float("-inf")
        while frontier:
            scored = []
            for layout in frontier:
                pc = self.evaluate(self._default_candidate(layout))
                scored.append((pc, layout))
            scored.sort(key=lambda t: (-t[0].score, t[1]))
            improved = scored and scored[0][0].score > best_score
            if improved:
                best_score = scored[0][0].score
            beam = scored[:self.beam_width]
            if not improved:
                break     # adding nodes stopped paying — construction done
            frontier = sorted({ext for _, layout in beam
                               for ext in self._extensions(layout)})
        # -- Phase B: router / suffix refinement of the leaders ---------
        leaders = sorted(self._measured.values(),
                         key=lambda c: (-c.score, c.cluster, c.router))
        for leader in leaders[:self.refine_top]:
            variants = [leader.cluster] + suffix_variants(
                leader.cluster, policies=self.policies,
                cache=self.try_cache)
            for layout in variants:
                for router in router_choices(layout, self.routers):
                    self.evaluate(Candidate(layout, router))
        ranked = sorted(self._measured.values(),
                        key=lambda c: (-c.score, c.cluster, c.router))
        return PlanResult(
            inventory=self.inventory.spec, workload=self.workload.spec,
            ranked=ranked, probes=list(self.probes),
            n_evaluations=self.n_evaluations, n_memo_hits=self.memo.hits,
            spec_kw=dict(self.spec_kw))


def plan_topology(inventory: "DeviceInventory | str",
                  workload: "WorkloadSpec | str", **kw) -> PlanResult:
    """One-call convenience: ``TopologyPlanner(...).plan()``."""
    return TopologyPlanner(inventory, workload, **kw).plan()


def hand_baselines(inventory: "DeviceInventory | str") -> Dict[str, str]:
    """The two layouts an operator writes without a planner, as canonical
    DSL: ``workers`` — every device a standalone worker (the homogeneous
    data-parallel reflex); ``pairs`` — greedily pair the fastest device
    with the slowest available (the all-cronus-pairs reflex), leftovers
    as workers. Both consume the whole rack — that is the point: hand
    layouts spend every device, the planner spends only the ones that
    pay."""
    if isinstance(inventory, str):
        inventory = DeviceInventory.parse(inventory)
    from repro.serving.hardware import DEVICES
    workers = [f"worker:{d}" for d, n in inventory.counts.items()
               for _ in range(n)]
    rack = DeviceInventory(dict(inventory.counts))
    pairs: List[str] = []
    while True:
        types = sorted(rack.counts, key=lambda d: (-DEVICES[d].flops, d))
        hi, lo = (types[0], types[-1]) if types else (None, None)
        if hi is None or hi == lo \
                or DEVICES[hi].flops <= DEVICES[lo].flops:
            break
        rack.take((hi, lo))
        pairs.append(f"cronus:{hi}+{lo}")
    pairs += [f"worker:{d}" for d, n in rack.counts.items()
              for _ in range(n)]
    return {"workers": canonical_cluster_spec(",".join(workers)),
            "pairs": canonical_cluster_spec(",".join(pairs))}
