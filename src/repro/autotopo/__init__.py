"""Auto-topology planner: search the heterogeneous placement space.

Given a spare rack (:class:`~repro.autoscale.inventory.DeviceInventory`)
and a workload (:class:`~repro.autotopo.space.WorkloadSpec`), find the
topology — endpoint grouping, device assignment, router and per-node
``@policy``/``@cache`` suffixes — that maximises SLO-sustainable
capacity per A100-equivalent device-cost, using
:func:`~repro.workloads.find_capacity` as the black-box evaluator. See
:mod:`repro.autotopo.space` for the candidate space and pruning rules,
:mod:`repro.autotopo.planner` for the search and the evaluation memo.
"""
from repro.autotopo.planner import (EvalMemo, PlanCandidate, PlanResult,
                                    TopologyPlanner, hand_baselines,
                                    plan_topology)
from repro.autotopo.space import (ARRIVAL_KINDS, PAIR_KINDS, TRACE_KINDS,
                                  Candidate, WorkloadSpec,
                                  enumerate_layouts, layout_cost_rate,
                                  layout_devices, node_templates,
                                  parse_workload, router_choices,
                                  suffix_variants)

__all__ = [
    "ARRIVAL_KINDS", "PAIR_KINDS", "TRACE_KINDS",
    "Candidate", "WorkloadSpec", "enumerate_layouts", "layout_cost_rate",
    "layout_devices", "node_templates", "parse_workload", "router_choices",
    "suffix_variants",
    "EvalMemo", "PlanCandidate", "PlanResult", "TopologyPlanner",
    "hand_baselines", "plan_topology",
]
