"""gemma3-27b [dense] — 5:1 local:global sliding-window pattern, 128k context.

[hf:google/gemma-3-1b-pt family scaled per assignment] 62 layers,
d_model=5376, 32 heads (GQA kv=16), d_ff=21504, vocab=262144.
Local layers: window=1024; every 6th layer is global. long_500k runs
natively (local layers bounded; global layers sequence-sharded decode).
"""
from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="gemma3-27b",
    arch_type="dense",
    n_layers=62,
    d_model=5_376,
    n_heads=32,
    n_kv_heads=16,
    d_ff=21_504,
    vocab_size=262_144,
    head_dim=128,
    qk_norm=True,               # gemma3 uses qk-norm
    window_size=1_024,          # native local window
    global_every=6,             # 5 local : 1 global
    rope_theta=1_000_000.0,
    citation="hf:google/gemma-3-1b-pt",
)

SMOKE_CONFIG = smoke_variant(CONFIG)
