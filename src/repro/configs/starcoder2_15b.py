"""starcoder2-15b [dense] — GQA kv=4, RoPE.

[arXiv:2402.19173] 40 layers, d_model=6144, 48 heads (GQA kv=4),
d_ff=24576, vocab=49152.
"""
from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="starcoder2-15b",
    arch_type="dense",
    n_layers=40,
    d_model=6_144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24_576,
    vocab_size=49_152,
    head_dim=128,
    rope_theta=100_000.0,
    swa_variant_window=4_096,   # SWA variant for long_500k only
    citation="arXiv:2402.19173",
)

SMOKE_CONFIG = smoke_variant(CONFIG)
