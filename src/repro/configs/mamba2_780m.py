"""mamba2-780m [ssm] — attention-free SSD (state-space duality).

[arXiv:2405.21060] 48 layers, d_model=1536, vocab=50280, ssm_state=128,
expand=2 => d_inner=3072, head_dim=64 => 48 ssm heads.
"""
from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="mamba2-780m",
    arch_type="ssm",
    n_layers=48,
    d_model=1_536,
    n_heads=1,                  # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,                     # no MLP block (mamba2 blocks only)
    vocab_size=50_280,
    head_dim=64,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_n_heads=48,             # d_inner 3072 / 64
    ssm_chunk=64,
    tie_embeddings=True,
    citation="arXiv:2405.21060",
)

SMOKE_CONFIG = smoke_variant(CONFIG)
