"""hymba-1.5b [hybrid] — parallel attention + mamba heads in every layer.

[arXiv:2411.13676] 32 layers, d_model=1600, 25 heads (GQA kv=5),
d_ff=5504, vocab=32001, ssm_state=16. Attention heads use a sliding
window (global on a few layers); SSM branch is mamba-style. long_500k
runs natively (constant SSM state + window-bounded attention).
"""
from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    n_layers=32,
    d_model=1_600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5_504,
    vocab_size=32_001,
    head_dim=64,                # 1600 / 25
    hybrid=True,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_n_heads=50,             # d_inner 3200 / 64
    ssm_chunk=64,
    window_size=1_024,          # sliding-window attention branch
    global_every=16,            # a few global layers
    citation="arXiv:2411.13676",
)

SMOKE_CONFIG = smoke_variant(CONFIG)
