"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 experts top-8.

[arXiv:2501.kimi2] 61 layers, d_model=7168, 64 heads (GQA kv=8),
expert d_ff=2048, vocab=163840, 384 routed experts top-8 + 1 shared,
first layer dense (deepseek-v3-style).
"""
from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    n_layers=61,
    d_model=7_168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2_048,                 # per-expert ffn
    vocab_size=163_840,
    head_dim=112,               # 7168 / 64
    n_experts=384,
    n_shared_experts=1,
    top_k=8,
    moe_dense_layers=1,
    moe_dense_d_ff=18_432,
    swa_variant_window=4_096,   # SWA variant for long_500k only
    citation="arXiv:2501.kimi2",
)

SMOKE_CONFIG = smoke_variant(CONFIG)
