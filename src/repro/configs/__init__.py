from repro.configs.base import (
    ARCH_IDS,
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    get_config,
    get_shape,
    smoke_variant,
)

__all__ = [
    "ARCH_IDS",
    "INPUT_SHAPES",
    "InputShape",
    "ModelConfig",
    "get_config",
    "get_shape",
    "smoke_variant",
]
