"""whisper-base [audio] — enc-dec transformer backbone, conv frontend stubbed.

[arXiv:2212.04356] Whisper base: 6 enc + 6 dec layers, d_model=512, 8 heads,
d_ff=2048, vocab=51865. Audio frontend (mel + conv) is a stub: input_specs
provides precomputed frame embeddings (1500 frames for 30 s audio).
"""
from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="whisper-base",
    arch_type="audio",
    n_layers=6,                 # decoder layers
    d_model=512,
    n_heads=8,
    n_kv_heads=8,               # GQA kv=8 (== MHA here)
    d_ff=2048,
    vocab_size=51_865,
    enc_dec=True,
    n_enc_layers=6,
    enc_seq_len=1_500,
    embeddings_input=True,      # encoder consumes precomputed frame embeddings
    rope_theta=10_000.0,        # (whisper uses learned abs pos; we use rope — noted in DESIGN.md)
    swa_variant_window=4_096,   # SWA variant enables long_500k decode (synthetic stress)
    citation="arXiv:2212.04356",
)

SMOKE_CONFIG = smoke_variant(CONFIG)
