"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution; ViT frontend stubbed.

[arXiv:2409.12191] 80 layers, d_model=8192, 64 heads (GQA kv=8),
d_ff=29568, vocab=152064. M-RoPE sections (t, h, w) = (16, 24, 24) over
head_dim=128 (pairs). Vision encoder + projector are a stub:
input_specs provides precomputed patch embeddings interleaved with text.
"""
from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    arch_type="vlm",
    n_layers=80,
    d_model=8_192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29_568,
    vocab_size=152_064,
    head_dim=128,
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    embeddings_input=True,      # mixed text-token + patch-embedding input
    swa_variant_window=4_096,   # SWA variant for long_500k only
    citation="arXiv:2409.12191",
)

SMOKE_CONFIG = smoke_variant(CONFIG)
