"""deepseek-v2-236b [moe] — MLA (kv_lora=512), 2 shared + 160 routed top-6.

[arXiv:2405.04434] 60 layers, d_model=5120, 128 heads, expert d_ff=1536,
vocab=102400. MLA: kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
nope_head_dim=128, v_head_dim=128. First layer dense (d_ff=12288).
"""
from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    n_layers=60,
    d_model=5_120,
    n_heads=128,
    n_kv_heads=128,             # MLA: effectively MHA over decompressed latents
    d_ff=1_536,                 # per-expert ffn
    vocab_size=102_400,
    head_dim=128,
    n_experts=160,
    n_shared_experts=2,
    top_k=6,
    moe_dense_layers=1,
    moe_dense_d_ff=12_288,
    mla_kv_lora_rank=512,
    mla_q_lora_rank=1_536,
    mla_rope_head_dim=64,
    mla_nope_head_dim=128,
    mla_v_head_dim=128,
    swa_variant_window=4_096,   # SWA variant for long_500k only
    citation="arXiv:2405.04434",
)

SMOKE_CONFIG = smoke_variant(CONFIG)
