"""qwen3-32b [dense] — qk_norm, GQA kv=8.

[hf:Qwen/Qwen3-8B family scaled per assignment] 64 layers, d_model=5120,
64 heads (GQA kv=8), d_ff=25600, vocab=151936, per-head RMSNorm on q/k.
"""
from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="qwen3-32b",
    arch_type="dense",
    n_layers=64,
    d_model=5_120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25_600,
    vocab_size=151_936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    swa_variant_window=4_096,   # SWA variant for long_500k only
    citation="hf:Qwen/Qwen3-8B",
)

SMOKE_CONFIG = smoke_variant(CONFIG)
