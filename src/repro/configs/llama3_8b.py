"""llama3-8b — the paper's primary evaluation model (Table 2, Fig. 4).

[arXiv:2407.21783] 32 layers, d_model=4096, 32 heads (GQA kv=8),
d_ff=14336, vocab=128256.
"""
from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="llama3-8b",
    arch_type="dense",
    n_layers=32,
    d_model=4_096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14_336,
    vocab_size=128_256,
    head_dim=128,
    rope_theta=500_000.0,
    swa_variant_window=4_096,
    citation="arXiv:2407.21783",
)

SMOKE_CONFIG = smoke_variant(CONFIG)
