"""deepseek-coder-33b [dense] — llama-arch GQA.

[arXiv:2401.14196] 62 layers, d_model=7168, 56 heads (GQA kv=8),
d_ff=19200, vocab=32256.
"""
from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    arch_type="dense",
    n_layers=62,
    d_model=7_168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19_200,
    vocab_size=32_256,
    head_dim=128,
    swa_variant_window=4_096,   # SWA variant for long_500k only
    citation="arXiv:2401.14196",
)

SMOKE_CONFIG = smoke_variant(CONFIG)
