"""Config system: model architecture configs, input shapes, registry.

Every assigned architecture gets one ``<id>.py`` module that exports
``CONFIG`` (full-size, exercised only via the dry-run) and
``SMOKE_CONFIG`` (reduced: <=2 layers, d_model<=512, <=4 experts; runs on CPU).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Tuple

# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

ARCH_TYPES = ("dense", "moe", "ssm", "hybrid", "audio", "vlm")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Unified architecture description covering all assigned families."""

    name: str
    arch_type: str                      # one of ARCH_TYPES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                   # 0 -> d_model // n_heads
    citation: str = ""

    # --- attention flavour ---
    qk_norm: bool = False               # qwen3-style per-head RMSNorm on q,k
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (t, h, w) dims
    # sliding window: per-layer pattern. window_size=0 -> full attention.
    window_size: int = 0
    # every `global_every`-th layer is global (gemma3 5:1 => 6)
    global_every: int = 0
    # SWA *variant* window, applied only for long-context decode (long_500k)
    # on otherwise-full-attention archs (task-sanctioned sub-quadratic variant).
    swa_variant_window: int = 0
    # MLA (deepseek-v2): latent KV compression
    mla_kv_lora_rank: int = 0
    mla_q_lora_rank: int = 0
    mla_rope_head_dim: int = 64
    mla_nope_head_dim: int = 128
    mla_v_head_dim: int = 128

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # dense d_ff used for first `moe_dense_layers` layers (deepseek-style)
    moe_dense_layers: int = 0
    moe_dense_d_ff: int = 0

    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_n_heads: int = 0                # mamba2 heads (d_inner // head_dim)
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64                 # SSD chunk length
    ssm_conv_width: int = 4

    # --- hybrid (hymba): parallel attn + ssm heads in the same layer ---
    hybrid: bool = False

    # --- encoder-decoder (whisper) ---
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq_len: int = 0                # encoder context (audio frames)

    # --- modality frontend stub (audio/vlm): inputs are embeddings ---
    embeddings_input: bool = False

    # --- misc ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.arch_type in ARCH_TYPES, self.arch_type

    # ----- derived quantities -------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    def layer_window(self, layer_idx: int) -> int:
        """Sliding-window size for a layer (0 = full attention)."""
        if self.window_size == 0:
            return 0
        if self.global_every and (layer_idx + 1) % self.global_every == 0:
            return 0  # global layer
        return self.window_size

    # ----- parameter counts (for roofline MODEL_FLOPS = 6 N D) ----------
    def param_count(self) -> int:
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        return _param_count(self, active_only=True)


def _attn_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    if cfg.mla_kv_lora_rank:
        r_kv, r_q = cfg.mla_kv_lora_rank, cfg.mla_q_lora_rank or cfg.d_model
        hd_n, hd_r, hd_v = cfg.mla_nope_head_dim, cfg.mla_rope_head_dim, cfg.mla_v_head_dim
        n = cfg.n_heads
        p = d * (r_kv + hd_r)                       # kv down-proj (+ rope k)
        p += r_kv * n * (hd_n + hd_v)               # kv up-proj
        if cfg.mla_q_lora_rank:
            p += d * r_q + r_q * n * (hd_n + hd_r)
        else:
            p += d * n * (hd_n + hd_r)
        p += n * hd_v * d                           # o proj
        return p
    hd = cfg.head_dim
    return d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d


def _mlp_params(d: int, d_ff: int) -> int:
    return 3 * d * d_ff  # SwiGLU: gate, up, down


def _ssm_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    d_inner = cfg.ssm_expand * d
    n_h = cfg.ssm_n_heads or max(1, d_inner // cfg.ssm_head_dim)
    p = d * (2 * d_inner + 2 * cfg.ssm_state + n_h)   # in_proj (x,z,B,C,dt)
    p += d_inner * cfg.ssm_conv_width                 # conv1d (depthwise)
    p += 2 * n_h                                      # A_log, D
    p += d_inner * d                                  # out_proj
    return p


def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    d = cfg.d_model
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    if cfg.embeddings_input:
        emb = cfg.vocab_size * d  # output head only; input is stub embeddings
    per_layer = 0
    total = emb
    n_layers = cfg.n_layers
    for i in range(n_layers):
        layer = 0
        if cfg.arch_type == "ssm":
            layer += _ssm_params(cfg)
        elif cfg.hybrid:
            layer += _attn_params(cfg) + _ssm_params(cfg) + _mlp_params(d, cfg.d_ff)
        else:
            layer += _attn_params(cfg)
            if cfg.is_moe and i >= cfg.moe_dense_layers:
                n_routed = cfg.top_k if active_only else cfg.n_experts
                layer += n_routed * _mlp_params(d, cfg.d_ff)
                layer += cfg.n_shared_experts * _mlp_params(d, cfg.d_ff)
            else:
                ff = cfg.moe_dense_d_ff or cfg.d_ff
                layer += _mlp_params(d, ff)
        total += layer
    if cfg.enc_dec:
        # encoder layers: attn + mlp; decoder already counted; cross-attn add
        enc = cfg.n_enc_layers * (_attn_params(cfg) + _mlp_params(d, cfg.d_ff))
        cross = cfg.n_layers * _attn_params(cfg)
        total += enc + cross
    return total


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "whisper-base",
    "mamba2-780m",
    "kimi-k2-1t-a32b",
    "deepseek-coder-33b",
    "deepseek-v2-236b",
    "starcoder2-15b",
    "qwen3-32b",
    "gemma3-27b",
    "hymba-1.5b",
    "qwen2-vl-72b",
    # the paper's own eval models
    "llama3-8b",
    "qwen2-7b",
)


def _module_name(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_module_name(arch_id)}")
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


def get_shape(shape_name: str) -> InputShape:
    return INPUT_SHAPES[shape_name]


def smoke_variant(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced variant of the same family: <=2 layers, d_model<=512, <=4 experts."""
    small = dict(
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 2,
        d_ff=512 if cfg.d_ff else 0,
        vocab_size=512,
        head_dim=64,
        # fp32 for CPU functional tests: bf16 ULP noise across batch shapes
        # flips greedy near-ties, breaking token-equality oracles
        dtype="float32",
    )
    if cfg.is_moe:
        small.update(n_experts=4, top_k=2, n_shared_experts=min(cfg.n_shared_experts, 1),
                     moe_dense_layers=min(cfg.moe_dense_layers, 1),
                     moe_dense_d_ff=512 if cfg.moe_dense_d_ff else 0)
    if cfg.mla_kv_lora_rank:
        small.update(mla_kv_lora_rank=32, mla_q_lora_rank=(64 if cfg.mla_q_lora_rank else 0),
                     mla_rope_head_dim=32, mla_nope_head_dim=32, mla_v_head_dim=32)
    if cfg.ssm_state:
        small.update(ssm_state=16, ssm_n_heads=8, ssm_head_dim=32, ssm_chunk=16)
    if cfg.window_size:
        small.update(window_size=64, global_every=cfg.global_every and 2)
    if cfg.mrope_sections:
        small.update(mrope_sections=(16, 8, 8))  # sums to head_dim(64)//2
    if cfg.enc_dec:
        small.update(n_enc_layers=2, enc_seq_len=64)
    small.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **small)
