"""qwen2-7b — the paper's second evaluation model (Table 2, Fig. 4).

[arXiv:2407.10671] 28 layers, d_model=3584, 28 heads (GQA kv=4),
d_ff=18944, vocab=152064.
"""
from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="qwen2-7b",
    arch_type="dense",
    n_layers=28,
    d_model=3_584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18_944,
    vocab_size=152_064,
    head_dim=128,
    rope_theta=1_000_000.0,
    swa_variant_window=4_096,
    citation="arXiv:2407.10671",
)

SMOKE_CONFIG = smoke_variant(CONFIG)
