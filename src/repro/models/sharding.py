"""Logical-axis sharding rules applied inside model code + name-based param specs.

The launcher installs a mapping from logical axis names to mesh axis names
via ``set_rules``; model code calls ``maybe_shard(x, 'batch', None, 'heads')``
at key activation points. With no rules installed (unit tests, single
device) these are no-ops.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

_RULES: Optional[dict] = None
_MESH = None


def set_rules(rules: Optional[dict], mesh=None):
    global _RULES, _MESH
    _RULES = rules
    _MESH = mesh


def get_rules():
    return _RULES


def get_mesh():
    return _MESH


def maybe_shard(x, *logical_axes):
    if _RULES is None:
        return x
    spec = P(*[_RULES.get(a) if a else None for a in logical_axes])
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# name-based parameter partition specs
# ---------------------------------------------------------------------------

def _base_spec(name: str, ndim: int, rules: dict, is_expert: bool = False) -> P:
    m = rules.get("model")
    table = {
        # attention
        "wq": P(None, m), "wk": P(None, m), "wv": P(None, m), "wo": P(m, None),
        # MLA
        "w_dkv": P(None, None), "w_kpe": P(None, None),
        "w_uk": P(None, m, None), "w_uv": P(None, m, None),
        "w_dq": P(None, None), "w_uq": P(None, m, None),
        # mlp
        "w_gate": P(None, m), "w_up": P(None, m), "w_down": P(m, None),
        # moe (expert-parallel)
        "router": P(None, m),
        # embeddings
        "embed": P(m, None), "head": P(None, m),
        # ssm
        "in_proj": P(None, m), "out_proj": P(m, None),
        "conv_w": P(None, m), "conv_b": P(m),
    }
    spec = table.get(name)
    if spec is None:
        return P(*([None] * ndim))
    if is_expert and name in ("w_gate", "w_up", "w_down"):
        # expert-stacked weights [..., E, d, f]: shard the expert dim
        return P(m, None, None)
    return spec


def param_pspec(path: tuple, leaf, rules: dict) -> P:
    """path: tuple of keys from tree_flatten_with_path; leaf: array/shape."""
    names = [getattr(k, "key", None) for k in path]
    names = [n for n in names if isinstance(n, str)]
    name = names[-1] if names else None
    is_expert = "moe" in names and "shared_" not in " ".join(names)
    ndim = len(leaf.shape)
    if name is None:
        return P(*([None] * ndim))
    base = _base_spec(name, ndim, rules, is_expert=is_expert)
    # account for extra leading stacking dims (layers, shared experts...)
    extra = ndim - len(base)
    if extra > 0:
        return P(*([None] * extra + list(base)))
    if extra < 0:  # scalar-ish leaves
        return P(*([None] * ndim))
    return base


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def divisible_spec(spec: P, shape, mesh) -> P:
    """Drop sharding on any dim the mesh axes don't evenly divide."""
    fixed = []
    for i, axis in enumerate(spec):
        n = _axis_size(mesh, axis)
        fixed.append(axis if (n > 1 and shape[i] % n == 0) or n == 1 else None)
    return P(*fixed)


def params_sharding_tree(params_or_shapes, mesh, rules: dict):
    from jax.sharding import NamedSharding

    def one(path, leaf):
        spec = param_pspec(path, leaf, rules)
        return NamedSharding(mesh, divisible_spec(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, params_or_shapes)
