"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def _rope_angles(positions, head_dim: int, theta: float):
    """positions [...,], returns (sin, cos) of shape [..., head_dim//2]."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., half]
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: [B, S, H, D]; positions: [B, S] -> rotated x (pairs = (even, odd halves))."""
    d = x.shape[-1]
    sin, cos = _rope_angles(positions, d, theta)       # [B,S,half]
    sin = sin[:, :, None, :]
    cos = cos[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections: Tuple[int, ...], theta: float = 1_000_000.0):
    """Qwen2-VL multimodal RoPE.

    x: [B, S, H, D]; positions3: [B, S, 3] (t, h, w) positions;
    sections: per-axis number of frequency PAIRS, sum(sections) == D//2.
    Text tokens use identical (t,h,w) which reduces to standard RoPE.
    """
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, d)
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # pick which position axis drives each frequency band
    axis_id = jnp.concatenate(
        [jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)]
    )                                                   # [half]
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),                 # [B,S,3]
        jnp.broadcast_to(axis_id, positions3.shape[:2] + (half,)).astype(jnp.int32),
        axis=-1,
    )                                                   # [B,S,half]
    ang = pos * freqs
    sin, cos = jnp.sin(ang)[:, :, None, :], jnp.cos(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def position_encode(x, positions, cfg):
    """Dispatch RoPE flavour from a ModelConfig."""
    if cfg.mrope_sections:
        if positions.ndim == 2:  # text-only: (t,h,w) all equal
            positions3 = jnp.broadcast_to(positions[..., None], positions.shape + (3,))
        else:
            positions3 = positions
        return apply_mrope(x, positions3, cfg.mrope_sections, cfg.rope_theta)
    return apply_rope(x, positions, cfg.rope_theta)
