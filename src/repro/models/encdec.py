"""Encoder-decoder backbone (Whisper-style). Conv/mel frontend is a stub:
the encoder consumes precomputed frame embeddings ``[B, S_enc, d]``.

Decoder layers: self-attention (cached, causal) -> cross-attention over the
encoder output (KV precomputed once at prefill and held in the cache — so a
partially-disaggregated prefill ships cross-KV + the self-KV prefix) -> MLP.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.layers import (dense_init, init_mlp, init_rmsnorm, rmsnorm,
                                 stack_layers, swiglu)
from repro.models.sharding import maybe_shard


class EncDecModel:
    def __init__(self, cfg, *, window_override: Optional[int] = None,
                 remat: bool = True, exact_moe: bool = False,
                 scan_unroll: bool = False):
        self.cfg = cfg
        self.remat = remat
        self.scan_unroll = scan_unroll
        if window_override is not None:
            widths = [window_override] * cfg.n_layers
        else:
            widths = [cfg.layer_window(i) for i in range(cfg.n_layers)]
        self.widths = jnp.array(widths, jnp.int32)
        self.dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    # ------------------------------------------------------------------
    def _init_enc_layer(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 2)
        return {
            "ln1": init_rmsnorm(cfg.d_model),
            "attn": attn.init_attention(ks[0], cfg),
            "ln2": init_rmsnorm(cfg.d_model),
            "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff),
        }

    def _init_dec_layer(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 3)
        return {
            "ln1": init_rmsnorm(cfg.d_model),
            "attn": attn.init_attention(ks[0], cfg),
            "ln_cross": init_rmsnorm(cfg.d_model),
            "cross": attn.init_attention(ks[1], cfg),
            "ln2": init_rmsnorm(cfg.d_model),
            "mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff),
        }

    def init_params(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 2 + cfg.n_enc_layers + cfg.n_layers)
        return {
            "embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), scale=0.02),
            "head": dense_init(ks[1], (cfg.d_model, cfg.vocab_size)),
            "enc_final_norm": init_rmsnorm(cfg.d_model),
            "final_norm": init_rmsnorm(cfg.d_model),
            "enc_layers": stack_layers(
                [self._init_enc_layer(ks[2 + i]) for i in range(cfg.n_enc_layers)]),
            "layers": stack_layers(
                [self._init_dec_layer(ks[2 + cfg.n_enc_layers + i])
                 for i in range(cfg.n_layers)]),
        }

    # ------------------------------------------------------------------
    def init_cache(self, batch: int, s_kv: int, s_enc: Optional[int] = None):
        cfg = self.cfg
        s_enc = s_enc or cfg.enc_seq_len
        kvh, hd, nl = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
        return {
            "pos": jnp.full((batch, s_kv), -1, jnp.int32),
            "stack": {
                "k": jnp.zeros((nl, batch, s_kv, kvh, hd), self.dtype),
                "v": jnp.zeros((nl, batch, s_kv, kvh, hd), self.dtype),
            },
            "cross_k": jnp.zeros((nl, batch, s_enc, kvh, hd), self.dtype),
            "cross_v": jnp.zeros((nl, batch, s_enc, kvh, hd), self.dtype),
        }

    # ------------------------------------------------------------------
    def encode(self, params, enc_emb, train: bool = False):
        """enc_emb [B, S_enc, d] (frontend stub output) -> enc_out."""
        cfg = self.cfg
        x = enc_emb.astype(self.dtype)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

        def body(xc, lp):
            h = rmsnorm(xc, lp["ln1"], cfg.norm_eps)
            xc = xc + attn.encoder_attention(lp["attn"], cfg, h, positions)
            h2 = rmsnorm(xc, lp["ln2"], cfg.norm_eps)
            xc = xc + swiglu(h2, lp["mlp"]["w_gate"], lp["mlp"]["w_up"],
                             lp["mlp"]["w_down"])
            return xc, 0.0

        if train and self.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["enc_layers"],
                            unroll=True if self.scan_unroll else 1)
        return rmsnorm(x, params["enc_final_norm"], cfg.norm_eps)

    def compute_cross_kv(self, params, enc_out):
        """Per-layer cross K/V from encoder output (stacked over layers)."""
        cfg = self.cfg
        b, s, _ = enc_out.shape
        kvh, hd = cfg.n_kv_heads, cfg.head_dim
        wk = params["layers"]["cross"]["wk"].astype(enc_out.dtype)  # [L,d,kv*hd]
        wv = params["layers"]["cross"]["wv"].astype(enc_out.dtype)
        ck = jnp.einsum("bsd,lde->lbse", enc_out, wk).reshape(-1, b, s, kvh, hd)
        cv = jnp.einsum("bsd,lde->lbse", enc_out, wv).reshape(-1, b, s, kvh, hd)
        return ck, cv

    # ------------------------------------------------------------------
    def forward(self, params, inputs, cache, cache_len, *, positions=None,
                kv_positions=None, enc_out=None, decode: bool = False,
                train: bool = False):
        """Decoder forward. inputs: token ids [B,S]. If ``enc_out`` is given
        (first prefill chunk), cross-KV is (re)computed and written to the
        cache; otherwise it is read from the cache."""
        cfg = self.cfg
        x = params["embed"].astype(self.dtype)[inputs]
        x = maybe_shard(x, "batch", "seq", None)
        b, s, _ = x.shape
        if positions is None:
            positions = cache_len[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]

        if enc_out is not None:
            cross_k, cross_v = self.compute_cross_kv(params, enc_out)
        else:
            cross_k, cross_v = cache["cross_k"], cache["cross_v"]

        if train:
            kv_pos, idx = positions, None
            stack_cache = {"_none": jnp.zeros((cfg.n_layers,), jnp.float32)}
        else:
            s_kv = cache["pos"].shape[1]
            idx = attn.write_indices(cache_len, s, s_kv)
            if kv_positions is None:
                kv_pos = attn.scatter_tokens(cache["pos"], positions, idx)
            else:
                kv_pos = kv_positions
            stack_cache = cache["stack"]

        def body(carry, xs):
            xc = carry
            lp, lc, width, ck_l, cv_l = xs
            h = rmsnorm(xc, lp["ln1"], cfg.norm_eps)
            a_out, new_lc = attn.attention_block(
                lp["attn"], cfg, h, positions, kv_pos, idx,
                None if train else lc, width)
            xc = xc + a_out
            hc = rmsnorm(xc, lp["ln_cross"], cfg.norm_eps)
            xc = xc + attn.cross_attention(lp["cross"], cfg, hc, ck_l, cv_l)
            h2 = rmsnorm(xc, lp["ln2"], cfg.norm_eps)
            xc = xc + swiglu(h2, lp["mlp"]["w_gate"], lp["mlp"]["w_up"],
                             lp["mlp"]["w_down"])
            return xc, (0.0 if train else new_lc)

        if train and self.remat:
            body = jax.checkpoint(body)
        x, new_stack = jax.lax.scan(
            body, x, (params["layers"], stack_cache, self.widths,
                      cross_k, cross_v),
            unroll=True if self.scan_unroll else 1)

        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = x @ params["head"].astype(x.dtype)
        logits = maybe_shard(logits, "batch", "seq", "vocab")
        new_cache = None
        if not train:
            new_cache = {"pos": kv_pos, "stack": new_stack,
                         "cross_k": cross_k, "cross_v": cross_v}
        return logits, new_cache, jnp.zeros((), jnp.float32)

    # ------------------------------------------------------------------
    def loss(self, params, batch):
        """batch: {'enc_emb': [B,S_enc,d], 'tokens': [B,S+1]}."""
        enc_out = self.encode(params, batch["enc_emb"], train=True)
        inputs, labels = batch["tokens"][:, :-1], batch["tokens"][:, 1:]
        b = inputs.shape[0]
        logits, _, _ = self.forward(params, inputs, None,
                                    jnp.zeros((b,), jnp.int32),
                                    enc_out=enc_out, train=True)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        return nll.mean()
