"""Mamba2 / SSD (state-space duality) blocks, chunked-scan + recurrent decode.

The SSD chunked algorithm (arXiv:2405.21060, Alg. "SSD") splits the sequence
into chunks of length Q: intra-chunk terms computed as attention-like
matmuls (the duality — these hit the MXU), inter-chunk terms via a small
recurrence over chunk states. The scan carries an initial state ``h0`` which
is exactly what partially-disaggregated prefill needs: the PPI ships its SSM
state (tiny: [H, P, N]) instead of a KV prefix, and the CPI's chunked prefill
resumes the scan from it.

Cache layout per layer: ``{'h': [B, H, P, N] fp32, 'conv': [B, W-1, Dconv]}``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, init_rmsnorm, rmsnorm


def ssm_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = cfg.ssm_n_heads or max(1, d_inner // cfg.ssm_head_dim)
    p = d_inner // n_heads
    n = cfg.ssm_state
    conv_dim = d_inner + 2 * n          # conv over (x, B, C), G=1 group
    return d_inner, n_heads, p, n, conv_dim


def init_ssm(key, cfg):
    d = cfg.d_model
    d_inner, h, p, n, conv_dim = ssm_dims(cfg)
    ks = jax.random.split(key, 5)
    proj_out = 2 * d_inner + 2 * n + h  # z, x, B, C, dt
    return {
        "in_proj": dense_init(ks[0], (d, proj_out)),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv_width, conv_dim), scale=0.3),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((h,), 0.01, jnp.float32))),
        "gate_norm": init_rmsnorm(d_inner),
        "out_proj": dense_init(ks[2], (d_inner, d)),
    }


def init_ssm_cache(cfg, batch: int, dtype=jnp.bfloat16):
    d_inner, h, p, n, conv_dim = ssm_dims(cfg)
    return {
        "h": jnp.zeros((batch, h, p, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
    }


# ---------------------------------------------------------------------------
# SSD chunked scan
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, a_neg, b_in, c_in, h0, chunk: int):
    """x [B,S,H,P]; dt [B,S,H] (>0); a_neg [H] (<0); b_in,c_in [B,S,N];
    h0 [B,H,P,N]. Returns (y [B,S,H,P], h_final [B,H,P,N])."""
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc = sp // q
    xc = x.reshape(bsz, nc, q, h, p).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, q, h).astype(jnp.float32)
    bc = b_in.reshape(bsz, nc, q, n).astype(jnp.float32)
    cc = c_in.reshape(bsz, nc, q, n).astype(jnp.float32)

    a = dtc * a_neg                                      # [B,nc,Q,H] log-decay
    a_cum = jnp.cumsum(a, axis=2)                        # inclusive
    xdt = xc * dtc[..., None]                            # [B,nc,Q,H,P]

    # intra-chunk (the "duality" matmuls)
    l_mat = jnp.exp(a_cum[:, :, :, None, :] - a_cum[:, :, None, :, :])  # [B,nc,Q,K,H]
    causal = jnp.tril(jnp.ones((q, q), bool))
    l_mat = jnp.where(causal[None, None, :, :, None], l_mat, 0.0)
    cb = jnp.einsum("bcqn,bckn->bcqk", cc, bc)
    y_intra = jnp.einsum("bcqk,bcqkh,bckhp->bcqhp", cb, l_mat, xdt)

    # chunk states
    decay_to_end = jnp.exp(a_cum[:, :, -1:, :] - a_cum)  # [B,nc,Q,H]
    states = jnp.einsum("bckh,bckn,bckhp->bchpn", decay_to_end, bc, xdt)

    # inter-chunk recurrence
    a_sum = a_cum[:, :, -1, :]                           # [B,nc,H]
    st_t = jnp.moveaxis(states, 1, 0)                    # [nc,B,H,P,N]
    as_t = jnp.moveaxis(a_sum, 1, 0)                     # [nc,B,H]

    def step(hprev, inp):
        s_c, asum = inp
        hnew = hprev * jnp.exp(asum)[:, :, None, None] + s_c
        return hnew, hprev

    h_final, h_prevs = jax.lax.scan(step, h0.astype(jnp.float32), (st_t, as_t))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                # [B,nc,H,P,N]

    y_inter = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", cc, h_prevs, jnp.exp(a_cum))
    y = (y_intra + y_inter).reshape(bsz, sp, h, p)[:, :s]
    return y.astype(x.dtype), h_final


def ssd_ref(x, dt, a_neg, b_in, c_in, h0):
    """Token-by-token recurrent oracle for ssd_chunked."""
    bsz, s, h, p = x.shape

    def step(hprev, inp):
        xt, dtt, bt, ct = inp                            # [B,H,P],[B,H],[B,N],[B,N]
        decay = jnp.exp(dtt * a_neg)                     # [B,H]
        upd = jnp.einsum("bh,bn,bhp->bhpn", dtt, bt, xt)
        hnew = hprev * decay[:, :, None, None] + upd
        yt = jnp.einsum("bn,bhpn->bhp", ct, hnew)
        return hnew, yt

    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(b_in, 1, 0).astype(jnp.float32),
          jnp.moveaxis(c_in, 1, 0).astype(jnp.float32))
    h_final, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h_final


# ---------------------------------------------------------------------------
# full mamba2 block (in_proj -> conv -> SSD -> gated norm -> out_proj)
# ---------------------------------------------------------------------------

def _split_proj(zxbcdt, cfg):
    d_inner, h, p, n, conv_dim = ssm_dims(cfg)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + conv_dim]
    dt = zxbcdt[..., d_inner + conv_dim:]
    return z, xbc, dt


def _causal_conv(xbc, conv_cache, w, bias, token_mask=None):
    """xbc [B,S,C]; conv_cache [B,W-1,C] (carry-in). Returns (out, new_cache).

    ``token_mask`` [B,S]: when the chunk carries trailing batch padding, the
    new conv cache must hold the last W-1 *valid* inputs, not the pads —
    gathered per-row at the valid count."""
    width = w.shape[0]
    full = jnp.concatenate([conv_cache.astype(xbc.dtype), xbc], axis=1)
    # depthwise conv, valid over the padded buffer
    out = sum(full[:, i:i + xbc.shape[1], :] * w[i].astype(xbc.dtype)
              for i in range(width))
    out = jax.nn.silu(out + bias.astype(xbc.dtype))
    if token_mask is None:
        new_cache = full[:, -(width - 1):, :]
    else:
        n_valid = jnp.sum(token_mask.astype(jnp.int32), axis=1)       # [B]
        idx = n_valid[:, None] + jnp.arange(width - 1)[None, :]       # [B,W-1]
        new_cache = jnp.take_along_axis(full, idx[:, :, None], axis=1)
    return out, new_cache.astype(conv_cache.dtype)


def ssm_block(params, cfg, x, cache, *, decode: bool = False,
              token_mask=None):
    """x [B,S,d]; cache {'h','conv'} -> (out [B,S,d], new_cache).

    ``token_mask`` [B,S] bool: False tokens (batch padding) must not touch
    the recurrent state — their dt is zeroed (decay=1, update=0). Unlike
    attention, SSM state has no positional masking, so this is load-bearing
    for padded serving batches."""
    d_inner, h, p, n, conv_dim = ssm_dims(cfg)
    zxbcdt = x @ params["in_proj"].astype(x.dtype)
    z, xbc, dt_raw = _split_proj(zxbcdt, cfg)
    xbc, new_conv = _causal_conv(xbc, cache["conv"], params["conv_w"],
                                 params["conv_b"], token_mask=token_mask)
    x_ssm = xbc[..., :d_inner]
    b_in = xbc[..., d_inner:d_inner + n]
    c_in = xbc[..., d_inner + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"])            # [B,S,H]
    if token_mask is not None:
        dt = jnp.where(token_mask[..., None], dt, 0.0)
    a_neg = -jnp.exp(params["A_log"])                    # [H]

    bsz, s, _ = x.shape
    xh = x_ssm.reshape(bsz, s, h, p)
    if decode and s == 1:
        y, h_final = _ssd_decode_step(xh, dt, a_neg, b_in, c_in, cache["h"])
    else:
        y, h_final = ssd_chunked(xh, dt, a_neg, b_in, c_in, cache["h"], cfg.ssm_chunk)
    y = y + xh.astype(jnp.float32).astype(y.dtype) * params["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(bsz, s, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    out = y @ params["out_proj"].astype(x.dtype)
    return out, {"h": h_final, "conv": new_conv}


def _ssd_decode_step(x, dt, a_neg, b_in, c_in, h0):
    """Single-token recurrent update. x [B,1,H,P]."""
    xt = x[:, 0].astype(jnp.float32)
    dtt = dt[:, 0]
    bt = b_in[:, 0].astype(jnp.float32)
    ct = c_in[:, 0].astype(jnp.float32)
    decay = jnp.exp(dtt * a_neg)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dtt, bt, xt)
    hnew = h0.astype(jnp.float32) * decay[:, :, None, None] + upd
    yt = jnp.einsum("bn,bhpn->bhp", ct, hnew)
    return yt[:, None].astype(x.dtype), hnew
