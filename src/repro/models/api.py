"""Unified model construction: ``build_model(cfg)`` -> DecoderModel | EncDecModel.

Every model exposes:
  init_params(key) -> params
  init_cache(batch, s_kv) -> cache
  forward(params, inputs, cache, cache_len, positions=..., decode=..., train=...)
  loss(params, batch)
"""
from __future__ import annotations

from typing import Optional

from repro.configs.base import ModelConfig
from repro.models.encdec import EncDecModel
from repro.models.transformer import DecoderModel


def build_model(cfg: ModelConfig, *, exact_moe: bool = False,
                window_override: Optional[int] = None, remat: bool = True,
                scan_unroll: bool = False, decode_write: str = "select"):
    if cfg.enc_dec:
        return EncDecModel(cfg, window_override=window_override, remat=remat,
                           scan_unroll=scan_unroll)
    return DecoderModel(cfg, exact_moe=exact_moe,
                        window_override=window_override, remat=remat,
                        scan_unroll=scan_unroll, decode_write=decode_write)
