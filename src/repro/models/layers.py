"""Basic layers: RMSNorm, SwiGLU MLP, embeddings, init helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, shape, scale: float | None = None, dtype=jnp.float32):
    """Truncated-normal fan-in init (stored fp32; cast at use-site)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype) * std)


def rmsnorm(x, weight, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def init_rmsnorm(d: int):
    # stored as delta from 1.0 (gemma-style), init 0
    return jnp.zeros((d,), jnp.float32)


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU MLP. x:[...,d]; w_gate/w_up:[d,f]; w_down:[f,d]."""
    h = jax.nn.silu(x @ w_gate.astype(x.dtype)) * (x @ w_up.astype(x.dtype))
    return h @ w_down.astype(x.dtype)


def init_mlp(key, d: int, f: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d, f)),
        "w_up": dense_init(k2, (d, f)),
        "w_down": dense_init(k3, (f, d)),
    }


def embed(tokens, table, dtype):
    return table.astype(dtype)[tokens]


def unembed(x, table):
    return x @ table.astype(x.dtype).T


def stack_layers(per_layer_params):
    """Stack a list of identical pytrees into one pytree with leading L dim."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *per_layer_params)
