"""Decoder-only model covering dense / MoE / SSM / hybrid / VLM families.

Layers are *stacked* (leading L dim on every param leaf) and executed with
``lax.scan`` so that the HLO (and compile time) is O(1) in depth — essential
for the 60+-layer full configs in the multi-pod dry-run. Heterogeneous
layers (MoE models' leading dense layers) live in a second, separately
stacked scan. Per-layer attention-window sizes ride along the scan as an
int32 array, so gemma3's 5:1 local:global pattern costs nothing extra.

One forward serves four modes:
  * train (no cache; attention over in-sequence k,v only),
  * full/partial prefill (writes into the cache),
  * chunked prefill continuation (queries attend to cache context + chunk),
  * decode (S=1; SSM uses the recurrent step).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (dense_init, init_mlp, init_rmsnorm, rmsnorm,
                                 stack_layers, swiglu)
from repro.models.sharding import maybe_shard


class DecoderModel:
    """Functional model; all state passes through explicitly."""

    def __init__(self, cfg, *, exact_moe: bool = False,
                 window_override: Optional[int] = None, remat: bool = True,
                 scan_unroll: bool = False, decode_write: str = "select"):
        self.cfg = cfg
        self.exact_moe = exact_moe
        self.remat = remat
        self.scan_unroll = scan_unroll  # unroll layer scans (cost calibration)
        # decode-step cache write strategy: "scatter" pairs with head-dim-
        # sharded decode caches (O(1) write bytes); "select" tolerates
        # sequence-sharded caches (see attention.scatter_tokens)
        self.decode_write = decode_write
        self.n_dense = cfg.moe_dense_layers if cfg.is_moe else 0
        self.n_stack = cfg.n_layers - self.n_dense
        if window_override is not None:
            widths = [window_override] * cfg.n_layers
        else:
            widths = [cfg.layer_window(i) for i in range(cfg.n_layers)]
        self.widths_dense = jnp.array(widths[: self.n_dense], jnp.int32)
        self.widths_stack = jnp.array(widths[self.n_dense:], jnp.int32)
        self.is_mla = cfg.mla_kv_lora_rank > 0
        self.attn_keys = ("ckv", "kpe") if self.is_mla else ("k", "v")
        self.dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    # ------------------------------------------------------------------
    # params
    # ------------------------------------------------------------------
    def _stack_kind(self) -> str:
        cfg = self.cfg
        if cfg.arch_type == "ssm":
            return "ssm"
        if cfg.hybrid:
            return "hybrid"
        if cfg.is_moe:
            return "moe"
        return "mlp"

    def _init_layer(self, key, kind: str):
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        p = {"ln1": init_rmsnorm(cfg.d_model)}
        if kind == "ssm":
            p["ssm"] = ssm_mod.init_ssm(ks[0], cfg)
            return p
        p["attn"] = attn.init_attention(ks[0], cfg)
        if kind == "hybrid":
            p["ssm"] = ssm_mod.init_ssm(ks[1], cfg)
            p["ln_attn_out"] = init_rmsnorm(cfg.d_model)
            p["ln_ssm_out"] = init_rmsnorm(cfg.d_model)
        p["ln2"] = init_rmsnorm(cfg.d_model)
        if kind == "moe":
            p["moe"] = moe_mod.init_moe(ks[2], cfg)
        elif kind == "dense_mlp":
            p["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.moe_dense_d_ff or cfg.d_ff)
        else:
            p["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff)
        return p

    def init_params(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 2 + cfg.n_layers)
        params = {
            "embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), scale=0.02),
            "final_norm": init_rmsnorm(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size))
        kind = self._stack_kind()
        if self.n_dense:
            params["dense_layers"] = stack_layers(
                [self._init_layer(ks[2 + i], "dense_mlp")
                 for i in range(self.n_dense)])
        params["layers"] = stack_layers(
            [self._init_layer(ks[2 + self.n_dense + i], kind)
             for i in range(self.n_stack)])
        return params

    # ------------------------------------------------------------------
    # cache
    # ------------------------------------------------------------------
    def _attn_layer_cache(self, n: int, batch: int, s_kv: int):
        cfg = self.cfg
        if self.is_mla:
            return {
                "ckv": jnp.zeros((n, batch, s_kv, cfg.mla_kv_lora_rank), self.dtype),
                "kpe": jnp.zeros((n, batch, s_kv, cfg.mla_rope_head_dim), self.dtype),
            }
        return {
            "k": jnp.zeros((n, batch, s_kv, cfg.n_kv_heads, cfg.head_dim), self.dtype),
            "v": jnp.zeros((n, batch, s_kv, cfg.n_kv_heads, cfg.head_dim), self.dtype),
        }

    def _ssm_layer_cache(self, n: int, batch: int):
        d_inner, h, p, nst, conv_dim = ssm_mod.ssm_dims(self.cfg)
        return {
            "h": jnp.zeros((n, batch, h, p, nst), jnp.float32),
            "conv": jnp.zeros((n, batch, self.cfg.ssm_conv_width - 1, conv_dim),
                              self.dtype),
        }

    def init_cache(self, batch: int, s_kv: int):
        kind = self._stack_kind()
        cache = {"pos": jnp.full((batch, max(s_kv, 1)), -1, jnp.int32)}
        stack = {}
        if kind in ("mlp", "moe", "hybrid"):
            stack.update(self._attn_layer_cache(self.n_stack, batch, s_kv))
        if kind in ("ssm", "hybrid"):
            stack.update(self._ssm_layer_cache(self.n_stack, batch))
        cache["stack"] = stack
        if self.n_dense:
            cache["dense"] = self._attn_layer_cache(self.n_dense, batch, s_kv)
        return cache

    def _dummy_cache(self, kind: str, n: int, batch: int):
        """Per-layer state for the cache-free training path."""
        if kind in ("ssm", "hybrid"):
            return self._ssm_layer_cache(n, batch)
        return {"_none": jnp.zeros((n,), jnp.float32)}

    # ------------------------------------------------------------------
    # one layer
    # ------------------------------------------------------------------
    def _layer(self, kind, lp, x, positions, kv_pos, idx, lc, width, decode, aux):
        cfg = self.cfg
        cache_free = idx is None
        token_mask = None if cache_free else positions >= 0
        if kind == "ssm":
            h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
            out, new_ssm = ssm_mod.ssm_block(
                lp["ssm"], cfg, h, {"h": lc["h"], "conv": lc["conv"]},
                decode=decode, token_mask=token_mask)
            return x + out, new_ssm, aux

        fn = attn.mla_attention_block if self.is_mla else attn.attention_block
        attn_lc = None if cache_free else {k: lc[k] for k in self.attn_keys}
        wmode = self.decode_write if decode else None
        h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
        if kind == "hybrid":
            a_out, new_kv = fn(lp["attn"], cfg, h, positions, kv_pos, idx,
                               attn_lc, width, write_mode=wmode)
            s_out, new_ssm = ssm_mod.ssm_block(
                lp["ssm"], cfg, h, {"h": lc["h"], "conv": lc["conv"]},
                decode=decode, token_mask=token_mask)
            mixed = 0.5 * (rmsnorm(a_out, lp["ln_attn_out"], cfg.norm_eps)
                           + rmsnorm(s_out, lp["ln_ssm_out"], cfg.norm_eps))
            x = x + mixed
            new_lc = {**(new_kv or {}), **new_ssm}
        else:
            a_out, new_kv = fn(lp["attn"], cfg, h, positions, kv_pos, idx,
                               attn_lc, width, write_mode=wmode)
            x = x + a_out
            new_lc = new_kv or {}

        h2 = rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if kind == "moe":
            m_out, a = moe_mod.moe_block(lp["moe"], cfg, h2, exact=self.exact_moe)
            aux = aux + a
        else:
            m_out = swiglu(h2, lp["mlp"]["w_gate"], lp["mlp"]["w_up"],
                           lp["mlp"]["w_down"])
        return x + m_out, new_lc, aux

    # ------------------------------------------------------------------
    # stacked-scan runner
    # ------------------------------------------------------------------
    def _run_stack(self, kind, stacked, widths, x, positions, kv_pos, idx,
                   stack_cache, decode, aux, train):
        def body(carry, xs):
            xc, auxc = carry
            lp, lc, width = xs
            xn, new_lc, auxn = self._layer(kind, lp, xc, positions, kv_pos,
                                           idx, lc, width, decode, auxc)
            return (xn, auxn), (0.0 if train else new_lc)

        if train and self.remat:
            body = jax.checkpoint(body)
        (x, aux), new_cache = jax.lax.scan(
            body, (x, aux), (stacked, stack_cache, widths),
            unroll=True if self.scan_unroll else 1)
        return x, (None if train else new_cache), aux

    # ------------------------------------------------------------------
    # forward
    # ------------------------------------------------------------------
    def embed_inputs(self, params, inputs):
        if inputs.ndim == 3:  # precomputed embeddings (audio / vlm frontend stub)
            return inputs.astype(self.dtype)
        return params["embed"].astype(self.dtype)[inputs]

    def forward(self, params, inputs, cache, cache_len, *, positions=None,
                kv_positions=None, decode: bool = False, train: bool = False):
        """inputs: tokens [B,S] int32 or embeddings [B,S,d].
        ``kv_positions`` [B,S_kv]: host-managed post-write cache positions
        (serving engines); if None the cache's own position buffer is used.
        Returns (logits [B,S,V], new_cache, aux)."""
        cfg = self.cfg
        kind = self._stack_kind()
        x = self.embed_inputs(params, inputs)
        x = maybe_shard(x, "batch", "seq", None)
        b, s, _ = x.shape
        if positions is None:
            positions = cache_len[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]

        aux = jnp.zeros((), jnp.float32)
        if train:
            kv_pos, idx = positions, None
            dense_cache = self._dummy_cache("mlp", self.n_dense, b)
            stack_cache = self._dummy_cache(kind, self.n_stack, b)
        else:
            s_kv = cache["pos"].shape[1]
            idx = attn.write_indices(cache_len, s, s_kv)
            if kv_positions is None:
                kv_pos = attn.scatter_tokens(cache["pos"], positions, idx)
            else:
                kv_pos = kv_positions
            stack_cache = cache["stack"]
            dense_cache = cache.get("dense")

        new_cache = None if train else {"pos": kv_pos}
        if self.n_dense:
            x, new_dense, aux = self._run_stack(
                "dense_mlp", params["dense_layers"], self.widths_dense, x,
                positions, kv_pos, idx, dense_cache, decode, aux, train)
            if not train:
                new_cache["dense"] = new_dense
        x, new_stack, aux = self._run_stack(
            kind, params["layers"], self.widths_stack, x, positions, kv_pos,
            idx, stack_cache, decode, aux, train)
        if not train:
            new_cache["stack"] = new_stack

        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["head"]
        logits = x @ head.astype(x.dtype)
        logits = maybe_shard(logits, "batch", "seq", "vocab")
        return logits, new_cache, aux

    # ------------------------------------------------------------------
    # loss
    # ------------------------------------------------------------------
    def loss(self, params, batch):
        """batch: {'tokens': [B,S+1]} or {'embeddings': [B,S,d], 'labels': [B,S]}."""
        if "embeddings" in batch:
            inputs, labels = batch["embeddings"], batch["labels"]
        else:
            inputs, labels = batch["tokens"][:, :-1], batch["tokens"][:, 1:]
        b = inputs.shape[0]
        logits, _, aux = self.forward(params, inputs, None,
                                      jnp.zeros((b,), jnp.int32), train=True)
        logits = logits.astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        loss = nll.mean()
        if self.cfg.is_moe:
            loss = loss + 0.01 * aux / max(self.n_stack, 1)
        return loss
