"""Attention: GQA with unified cache (full or ring), sliding windows, qk-norm,
MLA (DeepSeek-V2 latent attention), encoder/cross attention.

Unified cache semantics
-----------------------
A layer's KV cache is ``{'k': [B, S_kv, Kv, D], 'v': [B, S_kv, Kv, D]}`` plus
a *shared* (cross-layer) position buffer ``kv_pos [B, S_kv]`` initialised to
-1. New tokens are written at ``idx = (cache_len + arange(S_q)) % S_kv`` —
when ``S_kv`` is smaller than the sequence this is a ring buffer (sliding-
window variant); masks are derived purely from stored positions, so full and
ring caches share one code path:

    valid(q_pos, kv_pos) = kv_pos >= 0 and kv_pos <= q_pos
                           and (window == 0 or kv_pos > q_pos - window)

This one predicate implements causal masking, chunked-prefill context
masking, ring-buffer validity and sliding windows simultaneously.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, init_rmsnorm, rmsnorm
from repro.models.rope import position_encode

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_attention(key, cfg):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cfg.mla_kv_lora_rank:
        return _init_mla(key, cfg)
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (d, h * hd)),
        "wk": dense_init(ks[1], (d, kv * hd)),
        "wv": dense_init(ks[2], (d, kv * hd)),
        "wo": dense_init(ks[3], (h * hd, d)),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd)
        p["k_norm"] = init_rmsnorm(hd)
    return p


def _init_mla(key, cfg):
    d, h = cfg.d_model, cfg.n_heads
    r_kv, r_q = cfg.mla_kv_lora_rank, cfg.mla_q_lora_rank
    hd_n, hd_r, hd_v = cfg.mla_nope_head_dim, cfg.mla_rope_head_dim, cfg.mla_v_head_dim
    ks = jax.random.split(key, 8)
    p = {
        "w_dkv": dense_init(ks[0], (d, r_kv)),
        "w_kpe": dense_init(ks[1], (d, hd_r)),
        "kv_norm": init_rmsnorm(r_kv),
        "w_uk": dense_init(ks[2], (r_kv, h, hd_n)),
        "w_uv": dense_init(ks[3], (r_kv, h, hd_v)),
        "wo": dense_init(ks[4], (h * hd_v, d)),
    }
    if r_q:
        p["w_dq"] = dense_init(ks[5], (d, r_q))
        p["q_norm"] = init_rmsnorm(r_q)
        p["w_uq"] = dense_init(ks[6], (r_q, h, hd_n + hd_r))
    else:
        p["wq"] = dense_init(ks[5], (d, h, hd_n + hd_r))
    return p


# ---------------------------------------------------------------------------
# cache write helper
# ---------------------------------------------------------------------------

import os

# Cache-write strategy. "select" (default) writes via gather-from-new +
# where over an iota of the cache sequence axis: fully elementwise in the
# (possibly sequence-sharded) cache, so GSPMD keeps the KV cache sharded.
# "scatter" is the naive .at[].set() — data-dependent scatter indices force
# GSPMD to all-gather a sequence-sharded cache (measured: llama3-8b
# decode_32k went from 451 ms collective / 28.7 GB temp to ~0 — see
# EXPERIMENTS.md §Perf).
WRITE_MODE = os.environ.get("REPRO_CACHE_WRITE", "select")


def write_indices(cache_len, s_q: int, s_kv: int):
    """cache_len: [B] int32. Returns idx [B, s_q] (ring-modular, contiguous)."""
    return (cache_len[:, None] + jnp.arange(s_q, dtype=jnp.int32)[None, :]) % s_kv


def scatter_tokens(buf, new, idx, mode=None):
    """buf [B, S_kv, ...], new [B, S_q, ...], idx [B, S_q] (contiguous mod
    S_kv, from write_indices) -> updated buf.

    mode="scatter": true .at[].set — O(S_q) bytes written, in-place under
    donation, and GSPMD-shardable as long as the SEQUENCE dim of `buf` is
    unsharded (pair with head-dim-sharded decode caches; §Perf HC2-2).
    mode="select": gather+where over an iota — O(S_kv) bytes but fully
    elementwise, so it tolerates sequence-sharded caches (prefill chunks,
    long-context ring buffers)."""
    if (mode or WRITE_MODE) == "scatter":
        b = jnp.arange(buf.shape[0])[:, None]
        return buf.at[b, idx].set(new.astype(buf.dtype))
    bsz, s_kv = buf.shape[0], buf.shape[1]
    c = new.shape[1]
    if c == 1:
        # decode fast path (§Perf HC2-3): broadcast-compare + where, no
        # take_along_axis gather temp — one fused pass over the cache
        hit = (jnp.arange(s_kv, dtype=jnp.int32)[None, :] == idx)  # [B,S]
        hit = hit.reshape(hit.shape + (1,) * (new.ndim - 2))
        return jnp.where(hit, new.astype(buf.dtype), buf)
    start = idx[:, 0]                                     # [B]
    j = (jnp.arange(s_kv, dtype=jnp.int32)[None, :]
         - start[:, None]) % s_kv                         # [B, S_kv]
    valid = j < c
    jc = jnp.minimum(j, c - 1)
    idx_full = jc.reshape(jc.shape + (1,) * (new.ndim - 2))
    upd = jnp.take_along_axis(new.astype(buf.dtype),
                              jnp.broadcast_to(idx_full, (bsz, s_kv) + new.shape[2:]),
                              axis=1)
    mask = valid.reshape(valid.shape + (1,) * (new.ndim - 2))
    return jnp.where(mask, upd, buf)


# ---------------------------------------------------------------------------
# masking + core softmax-attention
# ---------------------------------------------------------------------------

def make_mask(q_pos, kv_pos, window, causal: bool = True):
    """q_pos [B,Sq], kv_pos [B,Skv], window scalar (0=full) -> [B,1,Sq,Skv] bool."""
    q = q_pos[:, :, None]
    k = kv_pos[:, None, :]
    valid = k >= 0
    if causal:
        valid &= k <= q
    win_ok = jnp.where(window > 0, k > q - window, True)
    return (valid & win_ok)[:, None, :, :]


def gqa_attend(q, k, v, mask, scale):
    """q [B,Sq,H,D]; k,v [B,Skv,Kv,D]; mask [B,1,Sq,Skv] -> [B,Sq,H,D].

    fp32 accumulation happens inside the dots (preferred_element_type), NOT
    by casting K/V up front — casting would materialize an fp32 copy of the
    whole KV cache each decode step (measured 2x memory-term inflation on
    deepseek-coder-33b decode_32k; EXPERIMENTS.md §Perf HC2-1)."""
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(mask[:, :, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def blocked_gqa_attend(q, k, v, q_pos, kv_pos, window, scale,
                       block_q: int = 512, block_k: int = 1024):
    """Flash-style attention in pure XLA (§Perf HC-prefill): lax.scan over
    KV blocks with running (m, l, acc), queries processed in blocks — the
    O(Sq x Skv) score matrix is never materialized. Same math as
    ``gqa_attend``+``make_mask`` (position-validity, causal, window).

    q [B,Sq,H,D]; k,v [B,Skv,Kv,D]; q_pos [B,Sq]; kv_pos [B,Skv].
    """
    b, sq, h, dh = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    pad_q = (-sq) % bq
    pad_k = (-skv) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)), constant_values=-1)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad_k)), constant_values=-1)
    nq, nk = (sq + pad_q) // bq, (skv + pad_k) // bk

    qb = q.reshape(b, nq, bq, kvh, g, dh)
    qpb = q_pos.reshape(b, nq, bq)
    kb = jnp.moveaxis(k.reshape(b, nk, bk, kvh, dh), 1, 0)    # [nk,B,bk,Kv,D]
    vb = jnp.moveaxis(v.reshape(b, nk, bk, kvh, dh), 1, 0)
    kpb = jnp.moveaxis(kv_pos.reshape(b, nk, bk), 1, 0)       # [nk,B,bk]

    def kv_step(carry, inp):
        m, lse, acc = carry          # m,lse [B,nq,Kv,g,bq]; acc [...,bq,D]
        kblk, vblk, kp = inp
        s = jnp.einsum("bnqkgd,bskd->bnkgqs", qb, kblk,
                       preferred_element_type=jnp.float32) * scale
        qp = qpb[:, :, None, None, :, None]                   # [B,nq,1,1,bq,1]
        kpx = kp[:, None, None, None, None, :]                # [B,1,1,1,1,bk]
        valid = (kpx >= 0) & (kpx <= qp) & (qp >= 0)
        valid &= jnp.where(window > 0, kpx > qp - window, True)
        s = jnp.where(valid, s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.where(valid, jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = lse * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bnkgqs,bskd->bnkgqd", p.astype(vblk.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), 0

    m0 = jnp.full((b, nq, kvh, g, bq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, nq, kvh, g, bq), jnp.float32)
    a0 = jnp.zeros((b, nq, kvh, g, bq, dh), jnp.float32)
    (m, lse, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                    (kb, vb, kpb))
    safe = jnp.where(lse == 0.0, 1.0, lse)
    out = acc / safe[..., None]                               # [B,nq,Kv,g,bq,D]
    out = jnp.moveaxis(out, 4, 2).reshape(b, nq * bq, h, dh)
    return out[:, :sq].astype(q.dtype)


# score-matrix size above which attention switches to the blocked path
# (keeps small/CPU-engine shapes on the exact-bit path used by the oracles)
BLOCKED_ATTN_THRESHOLD = 1 << 22


# ---------------------------------------------------------------------------
# GQA block with cache
# ---------------------------------------------------------------------------

def attention_block(p, cfg, x, positions, kv_pos, idx, layer_cache, window,
                    write_mode=None):
    """Self-attention with unified cache.

    x [B,Sq,d]; positions [B,Sq] (absolute); kv_pos [B,S_kv] (post-write,
    shared across layers); idx [B,Sq] write slots; layer_cache {'k','v'};
    window: traced int32 scalar (0 = full attention).
    Returns (out [B,Sq,d], new_layer_cache).
    """
    b, sq, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, sq, h, hd)
    k = (x @ p["wk"].astype(x.dtype)).reshape(b, sq, kvh, hd)
    v = (x @ p["wv"].astype(x.dtype)).reshape(b, sq, kvh, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = position_encode(q, positions, cfg)
    k = position_encode(k, positions, cfg)

    if layer_cache is None:  # cache-free (training) path: no scatter writes
        ck, cv = k, v
        new_cache = None
    else:
        ck = scatter_tokens(layer_cache["k"], k, idx, mode=write_mode)
        cv = scatter_tokens(layer_cache["v"], v, idx, mode=write_mode)
        new_cache = {"k": ck, "v": cv}
    if sq * ck.shape[1] >= BLOCKED_ATTN_THRESHOLD and sq > 1:
        out = blocked_gqa_attend(q, ck, cv, positions, kv_pos, window,
                                 hd ** -0.5)
    else:
        mask = make_mask(positions, kv_pos, window)
        out = gqa_attend(q, ck, cv, mask, hd ** -0.5)
    out = out.reshape(b, sq, h * hd) @ p["wo"].astype(x.dtype)
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA block (DeepSeek-V2): cache stores the compressed latent + rope key.
# Uses the weight-absorbed formulation for both prefill and decode so that a
# single code path serves chunked prefill (partial KV present) and decode.
# ---------------------------------------------------------------------------

def mla_attention_block(p, cfg, x, positions, kv_pos, idx, layer_cache,
                        window, write_mode=None):
    b, sq, d = x.shape
    h = cfg.n_heads
    hd_n, hd_r = cfg.mla_nope_head_dim, cfg.mla_rope_head_dim
    hd_v = cfg.mla_v_head_dim

    c_kv = rmsnorm(x @ p["w_dkv"].astype(x.dtype), p["kv_norm"], cfg.norm_eps)
    k_pe = (x @ p["w_kpe"].astype(x.dtype)).reshape(b, sq, 1, hd_r)
    k_pe = position_encode(k_pe, positions, cfg)[:, :, 0, :]

    if cfg.mla_q_lora_rank:
        q_lat = rmsnorm(x @ p["w_dq"].astype(x.dtype), p["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhe->bshe", q_lat, p["w_uq"].astype(x.dtype))
    else:
        q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(x.dtype))
    q_nope, q_pe = q[..., :hd_n], q[..., hd_n:]
    q_pe = position_encode(q_pe, positions, cfg)

    if layer_cache is None:  # cache-free (training) path
        cckv, ckpe = c_kv, k_pe
        new_cache = None
    else:
        cckv = scatter_tokens(layer_cache["ckv"], c_kv, idx, mode=write_mode)
        ckpe = scatter_tokens(layer_cache["kpe"], k_pe, idx, mode=write_mode)
        new_cache = {"ckv": cckv, "kpe": ckpe}

    # absorb W_uk into q: scores over the latent directly
    q_abs = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32),
                       p["w_uk"].astype(jnp.float32))
    scale = (hd_n + hd_r) ** -0.5
    scores = (jnp.einsum("bshr,btr->bhst", q_abs, cckv.astype(jnp.float32))
              + jnp.einsum("bshp,btp->bhst", q_pe.astype(jnp.float32),
                           ckpe.astype(jnp.float32))) * scale
    mask = make_mask(positions, kv_pos, window)        # [B,1,Sq,Skv]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bhst,btr->bshr", probs, cckv.astype(jnp.float32))
    out = jnp.einsum("bshr,rhv->bshv", out_lat, p["w_uv"].astype(jnp.float32))
    out = out.reshape(b, sq, h * hd_v).astype(x.dtype) @ p["wo"].astype(x.dtype)
    return out, new_cache


# ---------------------------------------------------------------------------
# encoder (bidirectional, no cache) and cross attention — whisper backbone
# ---------------------------------------------------------------------------

def encoder_attention(p, cfg, x, positions):
    b, s, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, h, hd)
    k = (x @ p["wk"].astype(x.dtype)).reshape(b, s, kvh, hd)
    v = (x @ p["wv"].astype(x.dtype)).reshape(b, s, kvh, hd)
    q = position_encode(q, positions, cfg)
    k = position_encode(k, positions, cfg)
    mask = jnp.ones((b, 1, s, s), bool)
    out = gqa_attend(q, k, v, mask, hd ** -0.5)
    return out.reshape(b, s, h * hd) @ p["wo"].astype(x.dtype)


def cross_attention(p, cfg, x, k_enc, v_enc):
    """x [B,Sq,d]; k_enc/v_enc [B,S_enc,Kv,D] (precomputed at prefill)."""
    b, sq, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, sq, h, hd)
    mask = jnp.ones((b, 1, sq, k_enc.shape[1]), bool)
    out = gqa_attend(q, k_enc, v_enc, mask, hd ** -0.5)
    return out.reshape(b, sq, h * hd) @ p["wo"].astype(x.dtype)


def cross_kv(p, cfg, enc_out):
    b, s, d = enc_out.shape
    kvh, hd = cfg.n_kv_heads, cfg.head_dim
    k = (enc_out @ p["wk"].astype(enc_out.dtype)).reshape(b, s, kvh, hd)
    v = (enc_out @ p["wv"].astype(enc_out.dtype)).reshape(b, s, kvh, hd)
    return k, v
