"""Mixture-of-Experts: top-k router + capacity-based sort/gather dispatch.

Design notes (TPU adaptation)
-----------------------------
Dispatch uses argsort + capacity gather into an ``[E, C, d]`` buffer followed
by batched expert matmuls ``ecd,edf->ecf``. This gives *active-FLOPs-exact*
cost accounting (matmul FLOPs = topk * T * cf * d * f * 6), unlike dense
one-hot dispatch (which would overcount by E/topk). With tokens sharded on
the 'data' axis and experts sharded on the 'model' axis, the gather/scatter
between the two layouts lowers to all-to-all-style collectives under GSPMD —
the expert-parallel pattern.

``exact`` mode sets capacity C = T (no token can be dropped since each token
routes to an expert at most once) — used by the functional serving engine
and smoke tests, where bit-exact routing matters more than peak efficiency.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, init_mlp, swiglu
from repro.models.sharding import maybe_shard


def init_moe(key, cfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 3 + cfg.n_shared_experts)
    p = {
        "router": dense_init(ks[0], (d, e), scale=d ** -0.5),
        "w_gate": dense_init(ks[1], (e, d, f)),
        "w_up": dense_init(ks[2], (e, d, f)),
        "w_down": dense_init(jax.random.fold_in(ks[2], 1), (e, f, d)),
    }
    for i in range(cfg.n_shared_experts):
        p[f"shared_{i}"] = init_mlp(ks[3 + i], d, f)
    return p


def _capacity(t: int, cfg, exact: bool) -> int:
    if exact:
        return t
    c = int(t * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    return max(min(t, c), min(t, 8))


def moe_block(p, cfg, x, exact: bool = False):
    """x [B,S,d] -> (out [B,S,d], aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xt = x.reshape(t, d)

    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)  # [T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, k)                         # [T,k]
    gate_w = gate_w / jnp.clip(gate_w.sum(-1, keepdims=True), 1e-9)    # renorm

    # load-balance auxiliary loss (Switch-style)
    me = probs.mean(0)                                                  # [E]
    ce = jnp.zeros((e,), jnp.float32).at[gate_idx.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)

    # §Perf HC1-2: under a mesh with expert parallelism, dispatch through
    # shard_map — every scatter/gather becomes device-LOCAL (GSPMD cannot
    # shard data-dependent scatters and all-gathers the [E,C,d] buffers:
    # measured 598 s collective on kimi-k2 prefill_32k).
    from repro.models.sharding import get_mesh, get_rules
    mesh, rules = get_mesh(), get_rules()
    use_shardmap = mesh is not None and rules and rules.get("experts") \
        and not exact
    if use_shardmap:
        b_ax = rules.get("batch")
        n_b = 1
        for a_ in (b_ax if isinstance(b_ax, (tuple, list)) else (b_ax,)):
            n_b *= mesh.shape[a_]
        # tokens must split evenly over the batch axes (single-token decode
        # steps, e.g. long_500k with batch 1, fall back to GSPMD dispatch)
        use_shardmap = t % n_b == 0 and t >= n_b
    if use_shardmap:
        out = _moe_dispatch_shardmap(p, cfg, xt, gate_w, gate_idx, mesh,
                                     rules)
        for i in range(cfg.n_shared_experts):
            sp = p[f"shared_{i}"]
            out = out + swiglu(xt, sp["w_gate"], sp["w_up"], sp["w_down"])
        return out.reshape(b, s, d), aux

    # ---- dispatch: sort (token,expert) pairs by expert ------------------
    cap = _capacity(t, cfg, exact)
    flat_e = gate_idx.reshape(-1)                                       # [T*k]
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)              # [T*k]
    flat_w = gate_w.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    # rank within expert group = position - start-of-group
    pos = jnp.arange(t * k, dtype=jnp.int32)
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    group_start = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                   jnp.cumsum(counts)[:-1]])
    rank = pos - group_start[se]
    keep = rank < cap
    slot_e = jnp.where(keep, se, 0)
    slot_c = jnp.where(keep, rank, cap - 1)

    buf = jnp.zeros((e, cap, d), xt.dtype)
    buf = buf.at[slot_e, slot_c].add(jnp.where(keep[:, None], xt[st], 0))
    buf = maybe_shard(buf, "experts", None, None)

    # ---- expert compute (E sharded on 'model' under pjit) ---------------
    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(xt.dtype)))
         * jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(xt.dtype)))
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(xt.dtype))
    out_buf = maybe_shard(out_buf, "experts", None, None)

    # ---- combine ---------------------------------------------------------
    gathered = out_buf[slot_e, slot_c]                                  # [T*k, d]
    contrib = jnp.where(keep[:, None], gathered * sw[:, None].astype(xt.dtype), 0)
    out = jnp.zeros((t, d), xt.dtype).at[st].add(contrib)

    for i in range(cfg.n_shared_experts):
        sp = p[f"shared_{i}"]
        out = out + swiglu(xt, sp["w_gate"], sp["w_up"], sp["w_down"])
    return out.reshape(b, s, d), aux


def _moe_dispatch_shardmap(p, cfg, xt, gate_w, gate_idx, mesh, rules):
    """Expert-parallel dispatch via shard_map (§Perf HC1-2).

    Layout: tokens are sharded over the batch axes and REPLICATED over the
    expert ('model') axis, so no token movement is needed at all: each
    (data i, model j) device routes data-block i's tokens to its LOCAL
    experts with device-local sort/scatter/gather, and the per-expert-shard
    partial outputs combine with one psum over the expert axis — the only
    collective this MoE layer needs (vs GSPMD all-gathering [E,C,d]
    dispatch buffers)."""
    from jax.sharding import PartitionSpec as P
    try:
        from jax import shard_map
    except ImportError:  # older jax
        from jax.experimental.shard_map import shard_map

    e, k, d = cfg.n_experts, cfg.top_k, cfg.d_model
    t = xt.shape[0]
    b_ax = rules.get("batch")
    m_ax = rules.get("experts")
    n_exp_shards = mesh.shape[m_ax]
    e_loc = e // n_exp_shards
    n_b = 1
    for a in (b_ax if isinstance(b_ax, (tuple, list)) else (b_ax,)):
        n_b *= mesh.shape[a]
    t_loc = t // n_b
    cap = max(min(t_loc, int(t_loc * k * cfg.capacity_factor / e) + 1),
              min(t_loc, 8))

    def local(xl, gw, gi, wg, wu, wd):
        # xl [T_loc, d]; gw/gi [T_loc, k]; wg/wu [E_loc, d, f]; wd [E_loc, f, d]
        j = jax.lax.axis_index(m_ax)
        e0 = j * e_loc
        flat_e = gi.reshape(-1) - e0                       # [T_loc*k]
        flat_t = jnp.repeat(jnp.arange(t_loc, dtype=jnp.int32), k)
        flat_w = gw.reshape(-1)
        local_sel = (flat_e >= 0) & (flat_e < e_loc)
        le = jnp.where(local_sel, flat_e, e_loc)           # bucket E_loc = misc
        order = jnp.argsort(le, stable=True)
        se, st, sw = le[order], flat_t[order], flat_w[order]
        sel = se < e_loc
        counts = jnp.zeros((e_loc + 1,), jnp.int32).at[le].add(1)
        group_start = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                       jnp.cumsum(counts)[:-1]])
        rank = jnp.arange(t_loc * k, dtype=jnp.int32) - group_start[se]
        keep = sel & (rank < cap)
        slot_e = jnp.where(keep, se, 0)
        slot_c = jnp.where(keep, rank, cap - 1)
        buf = jnp.zeros((e_loc, cap, d), xl.dtype)
        buf = buf.at[slot_e, slot_c].add(
            jnp.where(keep[:, None], xl[st], 0))
        h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg.astype(xl.dtype)))
             * jnp.einsum("ecd,edf->ecf", buf, wu.astype(xl.dtype)))
        out_buf = jnp.einsum("ecf,efd->ecd", h, wd.astype(xl.dtype))
        gathered = out_buf[slot_e, slot_c]
        contrib = jnp.where(keep[:, None],
                            gathered * sw[:, None].astype(xl.dtype), 0)
        out = jnp.zeros((t_loc, d), xl.dtype).at[st].add(contrib)
        return jax.lax.psum(out, m_ax)                     # combine shards

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(b_ax, None), P(b_ax, None), P(b_ax, None),
                  P(m_ax, None, None), P(m_ax, None, None),
                  P(m_ax, None, None)),
        out_specs=P(b_ax, None))
    return fn(xt, gate_w.astype(xt.dtype), gate_idx,
              p["w_gate"], p["w_up"], p["w_down"])


def moe_block_dense_ref(p, cfg, x):
    """Oracle: dense (all-experts) routing, exact combine. O(T*E*d*f)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(-1, d)
    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, k)
    gate_w = gate_w / jnp.clip(gate_w.sum(-1, keepdims=True), 1e-9)
    w = jnp.zeros_like(probs).at[jnp.arange(xt.shape[0])[:, None], gate_idx].set(gate_w)
    h = (jax.nn.silu(jnp.einsum("td,edf->tef", xt, p["w_gate"].astype(xt.dtype)))
         * jnp.einsum("td,edf->tef", xt, p["w_up"].astype(xt.dtype)))
    y = jnp.einsum("tef,efd->ted", h, p["w_down"].astype(xt.dtype))
    out = jnp.einsum("ted,te->td", y, w.astype(xt.dtype))
    for i in range(cfg.n_shared_experts):
        sp = p[f"shared_{i}"]
        out = out + swiglu(xt, sp["w_gate"], sp["w_up"], sp["w_down"])
    return out.reshape(b, s, d)
